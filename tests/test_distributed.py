"""Multi-device functional tests (8 fake CPU devices via subprocess).

XLA locks the host device count at first init, so each test spawns a
subprocess with XLA_FLAGS set — keeping the main pytest session at one
device as required (smoke tests must see 1 device).
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
        cwd=REPO,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_sharded_filter_insert_lookup():
    out = run_with_devices(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import sharded_filter as sf
        mesh = jax.make_mesh((8,), ("data",))
        cfg = sf.ShardedQFConfig(q=14, r=12, n_shards=8)
        state = sf.empty(cfg)
        B = 4096
        insert = jax.jit(sf.make_insert(cfg, mesh, B))
        lookup = jax.jit(sf.make_lookup(cfg, mesh, B))
        rng = np.random.default_rng(0)
        keys = jnp.asarray(rng.integers(0, 2**32, B, dtype=np.int64).astype(np.uint32))
        state = insert(state, keys)
        hit = lookup(state, keys)
        print("present:", bool(hit.all()))
        absent = jnp.asarray(
            rng.integers(0, 2**32, 4096, dtype=np.int64).astype(np.uint32)
        )
        fp = float(lookup(state, absent).mean())
        print("fp_ok:", fp < 0.01)
        """
    )
    assert "present: True" in out
    assert "fp_ok: True" in out


def test_train_step_multidevice_matches_single():
    """2x4 mesh train step: loss on the mesh == single-device loss."""
    out = run_with_devices(
        """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import sharding as shd
        from repro.configs import get_config, make_smoke
        from repro.models import model
        from repro.train import optimizer as optim, train_step as ts

        cfg = make_smoke(get_config("qwen3-8b")).replace(
            d_model=128, n_layers=2)
        ocfg = optim.OptConfig()
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)), jnp.int32),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)), jnp.int32),
        }
        state = ts.init_state(cfg, ocfg, 0)
        # single-device reference
        step0 = ts.make_train_step(cfg, ocfg)
        _, m0 = jax.jit(step0)(state, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        step_j, rules = ts.jit_train_step(cfg, ocfg, mesh, donate=False)
        with mesh:
            _, m1 = step_j(state, batch)
        d = abs(float(m0["loss"]) - float(m1["loss"]))
        print("loss match:", d < 1e-3, float(m0["loss"]), float(m1["loss"]))
        """
    )
    assert "loss match: True" in out


def test_decode_multidevice_matches_single():
    out = run_with_devices(
        """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import sharding as shd
        from repro.configs import get_config, make_smoke
        from repro.models import model
        from repro.serve.serve_step import cache_pspecs

        cfg = make_smoke(get_config("deepseek-7b"))
        rng = np.random.default_rng(1)
        params = model.init(cfg, 0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)
        }
        _, cache = model.prefill(params, cfg, batch, remat=False)
        tok = batch["tokens"][:, -1:]
        ref, _ = model.decode_step(params, cfg, cache, tok)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = shd.ShardingRules.for_config(mesh, cfg, decode=True)
        def serve(params, cache, tokens):
            with shd.use_rules(rules):
                return model.decode_step(params, cfg, cache, tokens)
        with mesh:
            got, _ = jax.jit(serve)(params, cache, tok)
        d = float(jnp.max(jnp.abs(ref - got))) / float(jnp.max(jnp.abs(ref)))
        print("decode match:", d < 2e-3, d)
        """
    )
    assert "decode match: True" in out


def test_gradient_compression_collective_shrinks():
    """With int8 EF compression the logical all-reduce payload is int8;
    verify numerics stay sane on a real 8-way data-parallel step."""
    out = run_with_devices(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config, make_smoke
        from repro.train import optimizer as optim, train_step as ts

        cfg = make_smoke(get_config("mamba2-130m"))
        ocfg = optim.OptConfig(compress_grads=True, lr=1e-3)
        rng = np.random.default_rng(0)
        state = ts.init_state(cfg, ocfg, 0)
        step = jax.jit(ts.make_train_step(cfg, ocfg))
        for i in range(3):
            batch = {
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (8, 64)), jnp.int32
                ),
                "targets": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (8, 64)), jnp.int32
                ),
            }
            state, m = step(state, batch)
            assert np.isfinite(float(m["loss"]))
        print("compressed training ok:", float(m["loss"]) > 0)
        """
    )
    assert "compressed training ok: True" in out


def test_elastic_restore_to_smaller_mesh():
    """Save on an 8-device mesh, restore onto a 4-device mesh (elastic)."""
    out = run_with_devices(
        """
        import tempfile, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, make_smoke
        from repro.models import model
        from repro.train import optimizer as optim, train_step as ts
        from repro.train.checkpoint import CheckpointManager
        from repro import sharding as shd

        cfg = make_smoke(get_config("gemma-7b"))
        ocfg = optim.OptConfig()
        state = ts.init_state(cfg, ocfg, 0)
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d)
        mgr.save(7, state)

        mesh2 = jax.make_mesh((1, 4), ("data", "model"))
        rules2 = shd.ShardingRules.for_config(mesh2, cfg)
        sspec = ts.state_pspecs(cfg, ocfg, rules2)
        sh = jax.tree.map(lambda s: NamedSharding(mesh2, s), sspec,
                          is_leaf=lambda x: isinstance(x, P))
        restored = mgr.restore(7, jax.eval_shape(lambda: state), shardings=sh)
        ok = all(
            np.allclose(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored))
        )
        print("elastic restore ok:", ok)
        """,
        n_devices=8,
    )
    assert "elastic restore ok: True" in out
