"""End-to-end behaviour tests: the full driver paths exercised as a user
would run them (dedup pipeline -> train steps -> checkpoint -> resume;
prefill -> decode with the AMQ prefix-cache front)."""


from repro.launch.train import main as train_main
from repro.launch.serve import main as serve_main


def test_train_driver_end_to_end(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    rc = train_main(
        [
            "--arch", "mamba2-130m", "--smoke", "--steps", "8",
            "--batch", "2", "--seq", "64",
            "--ckpt-dir", ckpt, "--ckpt-every", "4",
        ]
    )
    assert rc == 0
    # resume continues from the checkpoint (incl. dedup-filter state)
    rc = train_main(
        [
            "--arch", "mamba2-130m", "--smoke", "--steps", "10",
            "--batch", "2", "--seq", "64",
            "--ckpt-dir", ckpt, "--ckpt-every", "4", "--resume",
        ]
    )
    assert rc == 0


def test_serve_driver_end_to_end():
    rc = serve_main(
        [
            "--arch", "deepseek-7b", "--smoke",
            "--requests", "4", "--prompt-len", "16", "--gen", "3",
        ]
    )
    assert rc == 0


def test_train_with_compression_and_microbatches(tmp_path):
    rc = train_main(
        [
            "--arch", "qwen3-8b", "--smoke", "--steps", "4",
            "--batch", "4", "--seq", "32",
            "--microbatches", "2", "--compress-grads",
        ]
    )
    assert rc == 0
