"""Tests for the static-analysis pass (repro.analysis).

Three layers:

- **rule fixtures** — minimal snippets that trip each repro-lint rule,
  next to near-misses that must NOT trip (the false-positive budget);
- **committed-artifact round-trips** — baseline allowlist and trace
  manifest load/apply/diff, including seeded violations of each class
  exiting non-zero;
- **spec-checker structure** — malformed BlockSpec / ref-count
  mismatches are rejected; the real kernels validate clean.

Plus the regression test for the bug the trace audit surfaced: the
xor_fuse reference lookup ran fully eager (pjit=0) before PR 8's fix.
"""

from __future__ import annotations

import subprocess
import sys

import pytest

from repro.analysis import toml_lite, trace_audit
from repro.analysis.lint import (
    BaselineEntry,
    analyze_sources,
    apply_baseline,
    load_baseline,
)
from repro.analysis.spec_check import CapturedCall, validate_call


def rules_hit(code: str, path: str = "src/repro/fix.py") -> dict[str, int]:
    out: dict[str, int] = {}
    for f in analyze_sources({path: code}):
        out[f.rule] = out.get(f.rule, 0) + 1
    return out


JIT = "import jax\nimport jax.numpy as jnp\n"


# ---------------------------------------------------------------------------
# rule fixtures: one trip + one near-miss per rule
# ---------------------------------------------------------------------------


class TestRuleFixtures:
    def test_rl101_item_trips(self):
        code = JIT + "def f(x):\n    return x.item()\n"
        assert rules_hit(code).get("RL101") == 1

    def test_rl101_near_misses(self):
        code = JIT + (
            "def f(d, x):\n"
            "    a = d.items()\n"  # dict iteration, not a sync
            "    return x.item(0)\n"  # indexed .item is not the bare sync form
        )
        assert "RL101" not in rules_hit(code)

    def test_rl102_scalar_cast_trips(self):
        code = JIT + "def f(x):\n    return int(x) + float(x) + bool(x)\n"
        assert rules_hit(code).get("RL102") == 3

    def test_rl102_near_misses(self):
        code = JIT + (
            "LIMIT = 128\n"
            "def f(x, cfg):\n"
            "    a = int(x.shape[0])\n"  # static shape
            "    b = int(cfg.q)\n"  # config attribute (static root)
            "    c = int(LIMIT * 2)\n"  # module literal constant
            "    d = int('ff', 16)\n"  # two-arg form, host string parse
            "    return a + b + c + d\n"
        )
        assert "RL102" not in rules_hit(code)

    def test_rl103_numpy_roundtrip_trips(self):
        code = JIT + (
            "import numpy as np\n"
            "def f(x):\n"
            "    return np.asarray(x), jax.device_get(x)\n"
        )
        assert rules_hit(code).get("RL103") == 2

    def test_rl103_near_miss_jnp_asarray(self):
        code = JIT + "def f(x):\n    return jnp.asarray(x)\n"
        assert "RL103" not in rules_hit(code)

    def test_rl104_python_branch_in_jit_trips(self):
        code = JIT + (
            "@jax.jit\n"
            "def f(state):\n"
            "    if jnp.any(state.cells):\n"
            "        return state\n"
            "    return state\n"
        )
        assert rules_hit(code).get("RL104") == 1

    def test_rl104_not_reported_outside_jit(self):
        code = JIT + (
            "def f(state):\n"
            "    if jnp.any(state.cells):\n"
            "        return state\n"
            "    return state\n"
        )
        assert "RL104" not in rules_hit(code)

    def test_rl105_mode_resolve_in_jit_trips(self):
        code = JIT + (
            "from repro.kernels import dispatch\n"
            "@jax.jit\n"
            "def f(x):\n"
            "    m = dispatch.resolve()\n"
            "    return x\n"
        )
        assert rules_hit(code).get("RL105") == 1

    def test_rl105_eager_wrapper_is_fine(self):
        code = JIT + (
            "from repro.kernels import dispatch\n"
            "def wrapper(x):\n"
            "    m = dispatch.resolve()\n"
            "    return x\n"
        )
        assert "RL105" not in rules_hit(code)

    def test_rl106_bare_sentinel_compare_trips(self):
        code = JIT + "def f(x):\n    return x == 2**31 - 1\n"
        assert rules_hit(code).get("RL106") == 1

    def test_rl106_dtype_wrapped_sentinel_is_fine(self):
        code = JIT + (
            "def f(x):\n"
            "    return (x == jnp.int32(2**31 - 1)) | (x == 5)\n"
        )
        assert "RL106" not in rules_hit(code)

    def test_rl107_state_thread_without_donate_trips(self):
        code = JIT + (
            "@jax.jit\n"
            "def step(state, keys):\n"
            "    return state._replace(n=state.n + 1)\n"
        )
        assert rules_hit(code).get("RL107") == 1

    def test_rl107_donated_state_is_fine(self):
        code = JIT + (
            "import functools\n"
            "@functools.partial(jax.jit, donate_argnums=0)\n"
            "def step(state, keys):\n"
            "    return state._replace(n=state.n + 1)\n"
        )
        assert "RL107" not in rules_hit(code)

    def test_jit_reachability_escalates_severity(self):
        # the same construct is a warning in host code, an error when a
        # jit-rooted function can reach it through the call graph
        host = JIT + "def helper(x):\n    return int(x)\n"
        sevs = [f.severity for f in analyze_sources({"src/repro/fix.py": host})]
        assert sevs == ["warning"]
        jit = host + "@jax.jit\ndef root(x):\n    return helper(x)\n"
        sevs = [f.severity for f in analyze_sources({"src/repro/fix.py": jit})]
        assert sevs == ["error"]


# ---------------------------------------------------------------------------
# baseline allowlist round-trip
# ---------------------------------------------------------------------------


class TestBaseline:
    CODE = JIT + "def f(x):\n    return int(x)\n"

    def test_covered_finding_passes(self):
        findings = analyze_sources({"src/repro/fix.py": self.CODE})
        res = apply_baseline(
            findings,
            [BaselineEntry("RL102", "src/repro/fix.py", "known host code", count=1)],
        )
        assert res.ok and res.covered == 1

    def test_count_overflow_fails(self):
        code = JIT + "def f(x):\n    return int(x) + int(x)\n"
        findings = analyze_sources({"src/repro/fix.py": code})
        res = apply_baseline(
            findings,
            [BaselineEntry("RL102", "src/repro/fix.py", "one known site", count=1)],
        )
        assert not res.ok and res.problems

    def test_stale_entry_noted_but_passes(self):
        res = apply_baseline(
            [], [BaselineEntry("RL102", "src/repro/gone.py", "was removed")]
        )
        assert res.ok and len(res.stale) == 1

    def test_uncovered_finding_fails(self):
        findings = analyze_sources({"src/repro/fix.py": self.CODE})
        assert not apply_baseline(findings, []).ok

    def test_load_rejects_missing_reason(self, tmp_path):
        p = tmp_path / "baseline.toml"
        p.write_text('[[allow]]\nrule = "RL102"\npath = "a.py"\n')
        with pytest.raises(ValueError):
            load_baseline(str(p))

    def test_load_roundtrip(self, tmp_path):
        p = tmp_path / "baseline.toml"
        p.write_text(
            "[[allow]]\n"
            'rule = "RL103"\n'
            'path = "src/repro/a.py"\n'
            'func = "F.g"\n'
            "count = 2\n"
            'reason = "because"\n'
        )
        (e,) = load_baseline(str(p))
        assert (e.rule, e.path, e.func, e.count) == (
            "RL103", "src/repro/a.py", "F.g", 2,
        )


# ---------------------------------------------------------------------------
# toml_lite fallback parser
# ---------------------------------------------------------------------------


class TestTomlLite:
    def test_sections_arrays_and_types(self):
        data = toml_lite.loads(
            "[tool.demo]\n"
            'name = "x"  # comment\n'
            "n = 3\n"
            "ratio = 1.5\n"
            "on = true\n"
            'paths = [\n  "a",\n  "b",\n]\n'
            "[[tool.demo.allow]]\n"
            'rule = "R1"\n'
            "[[tool.demo.allow]]\n"
            'rule = "R2"\n'
        )
        sec = data["tool"]["demo"]
        assert sec["name"] == "x" and sec["n"] == 3 and sec["ratio"] == 1.5
        assert sec["on"] is True and sec["paths"] == ["a", "b"]
        assert [e["rule"] for e in sec["allow"]] == ["R1", "R2"]

    def test_malformed_raises(self):
        with pytest.raises(ValueError):
            toml_lite.loads("this is not toml\n")


# ---------------------------------------------------------------------------
# trace audit: manifest round-trip + seeded violations
# ---------------------------------------------------------------------------


def _fam(status="traced", eqns=100, prims=None):
    e = {"status": status}
    if status == "traced":
        e["eqns"] = eqns
        e["prims"] = prims or {"add": 3, "pjit": 1}
    return e


class TestTraceAudit:
    def test_manifest_roundtrip(self, tmp_path):
        cur = {"families": {"qf": {"contains": _fam()}}}
        path = str(tmp_path / "m.json")
        trace_audit.write_manifest(cur, path)
        man = trace_audit.load_manifest(path)
        assert man["families"] == cur["families"]
        lines, ok = trace_audit.diff(cur, man)
        assert ok and not any(line.startswith("FAIL") for line in lines)

    def test_status_change_fails(self):
        cur = {"families": {"qf": {"contains": _fam(status="host")}}}
        man = {"families": {"qf": {"contains": _fam()}}}
        lines, ok = trace_audit.diff(cur, man)
        assert not ok and any("status" in line for line in lines)

    def test_eqn_blowup_fails(self):
        cur = {"families": {"qf": {"contains": _fam(eqns=500)}}}
        man = {"families": {"qf": {"contains": _fam(eqns=100)}}}
        lines, ok = trace_audit.diff(cur, man)
        assert not ok and any("blow-up" in line for line in lines)

    def test_new_op_fails_until_update(self):
        cur = {"families": {"qf": {"contains": _fam(), "probe": _fam()}}}
        man = {"families": {"qf": {"contains": _fam()}}}
        _, ok = trace_audit.diff(cur, man)
        assert not ok

    def test_prim_drift_notes_unless_strict(self):
        cur = {"families": {"qf": {"contains": _fam(prims={"add": 3, "mul": 1})}}}
        man = {"families": {"qf": {"contains": _fam()}}}
        lines, ok = trace_audit.diff(cur, man, strict=False)
        assert ok and any(line.startswith("note") for line in lines)
        _, ok = trace_audit.diff(cur, man, strict=True)
        assert not ok

    def test_forbidden_primitive_detected(self):
        cur = {
            "families": {
                "qf": {"insert": _fam(prims={"add": 1, "pure_callback": 1})}
            }
        }
        hits = trace_audit.forbidden_hits(cur)
        assert len(hits) == 1 and "pure_callback" in hits[0]

    def test_live_trace_matches_committed_manifest_for_qf(self):
        cur = trace_audit.collect(families=["qf"])
        man = trace_audit.load_manifest()
        assert man is not None, "committed trace_manifest.json missing"
        sub = {
            "families": {
                k: v for k, v in man["families"].items() if k in cur["families"]
            }
        }
        lines, ok = trace_audit.diff(cur, sub)
        assert ok, "\n".join(lines)
        assert not trace_audit.forbidden_hits(cur)


class TestFuseLookupCompiled:
    def test_xor_fuse_contains_traces_compiled(self):
        """Regression: the reference binary-fuse lookup silently ran
        fully eager (pjit=0 in its jaxpr) until it was jitted with the
        config static — the exact bug class the trace audit exists to
        catch."""
        import jax

        from repro import filters

        cfg, state = filters.make("xor_fuse", capacity=128, keys=trace_audit._keys(32))
        jaxpr = jax.make_jaxpr(lambda s, k: filters.contains(cfg, s, k))(
            state, trace_audit._keys(16)
        )
        _, prims = trace_audit._count_jaxpr(jaxpr)
        assert prims.get("pjit", 0) >= 1


# ---------------------------------------------------------------------------
# spec checker: malformed launches rejected, real kernels clean
# ---------------------------------------------------------------------------


class _Spec:
    def __init__(self, block_shape, index_map):
        self.block_shape = block_shape
        self.index_map = index_map


def _call(**kw):
    base = dict(
        kernel_name="k",
        kernel_params=None,
        grid=(4,),
        num_scalar_prefetch=0,
        in_specs=[_Spec((1, 8), lambda t: (t, 0))],
        out_specs=[_Spec((1, 8), lambda t: (t, 0))],
        operand_shapes=[(4, 8)],
        scalar_values=[],
        out_shapes=[((4, 8), "int32")],
    )
    base.update(kw)
    return CapturedCall(**base)


class TestSpecChecker:
    def test_wellformed_launch_clean(self):
        assert validate_call(_call()) == []

    def test_tile_not_dividing_plane_rejected(self):
        bad = _call(in_specs=[_Spec((1, 7), lambda t: (t, 0))])
        assert any("does not divide" in p for p in validate_call(bad))

    def test_index_map_out_of_bounds_rejected(self):
        bad = _call(in_specs=[_Spec((1, 8), lambda t: (t + 1, 0))])
        assert any("out of bounds" in p for p in validate_call(bad))

    def test_operand_vs_spec_count_mismatch_rejected(self):
        bad = _call(operand_shapes=[(4, 8), (4, 8)])
        assert any("scalar-prefetch" in p for p in validate_call(bad))

    def test_kernel_arity_mismatch_rejected(self):
        bad = _call(kernel_params=5)  # needs 0 scalar + 1 in + 1 out = 2
        assert any("kernel body takes" in p for p in validate_call(bad))

    def test_index_map_uses_scalar_prefetch_values(self):
        import numpy as np

        # blk[t] style map: in-bounds only because of the clip the
        # wrapper applied to the prefetched block indices
        blk = np.asarray([0, 1, 2, 2], np.int32)
        out = [_Spec((1, 8), lambda t, b: (t, 0))]
        call = _call(
            num_scalar_prefetch=1,
            scalar_values=[blk],
            in_specs=[_Spec((1, 8), lambda t, b: (b[t], 0))],
            out_specs=out,
        )
        assert validate_call(call) == []
        unclipped = np.asarray([0, 1, 2, 3], np.int32)  # 3 -> off the plane
        call = _call(
            num_scalar_prefetch=1,
            scalar_values=[unclipped],
            in_specs=[_Spec((1, 8), lambda t, b: (b[t] + 1, 0))],
            out_specs=out,
        )
        assert any("out of bounds" in p for p in validate_call(call))

    def test_real_kernels_validate_clean(self):
        from repro.analysis.spec_check import (
            KERNELS,
            capture_kernel_calls,
        )

        for spec in KERNELS:
            calls = capture_kernel_calls(spec.driver)
            assert calls, f"{spec.entry}: no launch captured"
            for call in calls:
                assert validate_call(call) == [], spec.entry


# ---------------------------------------------------------------------------
# CLI: committed artifacts keep `python -m repro.analysis` green
# ---------------------------------------------------------------------------


class TestCli:
    @pytest.mark.parametrize("sub", ["lint", "spec"])
    def test_subcommand_exits_zero(self, sub):
        from repro.analysis.__main__ import main

        assert main([sub]) == 0

    def test_module_invocation(self):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "lint"],
            capture_output=True,
            text=True,
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr
