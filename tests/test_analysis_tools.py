"""Unit tests for the roofline/HLO analysis tooling and sharding rules."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H
from repro.launch import roofline as rf


class TestHloAnalysis:
    def test_scan_trip_count_multiplied(self):
        def f(x, w):
            def body(c, wi):
                return jnp.tanh(c @ wi), None

            y, _ = jax.lax.scan(body, x, w)
            return y

        x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
        c = jax.jit(f).lower(x, w).compile()
        got = H.analyze(c.as_text())["flops"]
        want = 2 * 10 * 64 * 128 * 128
        assert abs(got - want) / want < 0.01
        # raw xla under-counts by ~the trip count (regression canary)
        raw = c.cost_analysis()
        if isinstance(raw, (list, tuple)):  # jax<=0.4 returns one dict per program
            raw = raw[0]
        assert raw["flops"] < want / 5

    def test_grad_remat_flops(self):
        def f(x, w):
            body = jax.checkpoint(
                lambda c, wi: (jnp.tanh(c @ wi), None),
                policy=jax.checkpoint_policies.nothing_saveable,
            )

            def loss(x, w):
                y, _ = jax.lax.scan(body, x, w)
                return jnp.sum(y)

            return jax.grad(loss, argnums=1)(x, w)

        x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)
        c = jax.jit(f).lower(x, w).compile()
        got = H.analyze(c.as_text())["flops"]
        # fwd + remat-fwd + 2x bwd = 4 matmul-equivalents
        want = 4 * 2 * 12 * 64 * 128 * 128
        assert abs(got - want) / want < 0.05

    def test_collectives_parsed(self):
        mesh = jax.make_mesh((1,), ("d",))
        from jax.sharding import NamedSharding, PartitionSpec as P

        def f(x):
            return jax.lax.with_sharding_constraint(
                x.sum(), NamedSharding(mesh, P())
            )

        # single-device: no collectives expected; parser returns zeros
        c = jax.jit(f).lower(jax.ShapeDtypeStruct((128,), jnp.float32)).compile()
        coll = H.analyze(c.as_text())["collectives"]
        assert coll["total"] == 0

    def test_shape_parsing_with_index_comments(self):
        text = """
ENTRY %main (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  %w = (s32[], f32[8,8]{1,0}, /*index=5*/f32[16,16]{1,0}) while(%t), condition=%c, body=%b, backend_config={"known_trip_count":{"n":"3"}}
  ROOT %r = f32[4]{0} add(%p, %p)
}
%b (a: s32[]) -> s32[] {
  %a = s32[] parameter(0)
  %d = f32[8,8]{1,0} dot(%x, %y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
        mod = H.HloModule(text)
        whiles = [
            i
            for comp in mod.computations.values()
            for i in comp.instrs
            if i.op == "while"
        ]
        assert len(whiles) == 1 and mod._trip(whiles[0]) == 3


class TestRoofline:
    def test_terms_and_bound(self):
        r = rf.Roofline(
            flops=197e12, bytes_accessed=819e9 * 2, coll_bytes=50e9 / 2, chips=4,
            model_flops=4 * 197e12 * 0.5,
        )
        assert r.t_compute == pytest.approx(1.0)
        assert r.t_memory == pytest.approx(2.0)
        assert r.t_collective == pytest.approx(0.5)
        assert r.bound == "memory"
        assert r.mfu == pytest.approx(0.25)

    def test_model_flops_train_vs_decode(self):
        from repro.configs import get_config

        cfg = get_config("qwen3-8b")
        tr = rf.model_flops_estimate(cfg, "train", 256, 4096)
        de = rf.model_flops_estimate(cfg, "decode", 128, 32768)
        assert tr > 6 * cfg.param_count() * 256 * 4096 * 0.99
        assert de < tr / 1000


class TestShardingRules:
    def test_divisibility_fallbacks(self):
        from repro import sharding as shd
        from repro.configs import get_config

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # single-device mesh: everything trivially divides
        rules = shd.ShardingRules.for_config(mesh, get_config("qwen3-8b"))
        assert rules.spec(("batch", None)) is not None

    def test_spec_dedups_reused_axes(self):
        from repro import sharding as shd

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = shd.ShardingRules(
            mesh=mesh, mapping={"batch": ("data",), "embed": ("data",)}
        )
        spec = rules.spec(("batch", "embed"))
        # embed must NOT reuse the data axis already taken by batch
        assert spec[0] == ("data",) or spec[0] == "data"
        assert spec[1] is None

    def test_shape_aware_fallback(self):
        from repro import sharding as shd
        from jax.sharding import PartitionSpec

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = shd.ShardingRules(mesh=mesh, mapping={"ffn": ("model",)})
        # dim not divisible by axis size 1? always divisible; simulate via
        # explicit check that shape-aware path returns a PartitionSpec
        assert isinstance(rules.spec(("ffn",), (7,)), PartitionSpec)
