"""LSM-style steady state (``steady_qf``): exactness under settling.

Pins the claims that make the always-on write buffer + background
settle safe to leave permanently enabled:

* **settle-cursor exactness** — while a settle drains, membership over
  the table prefix, both in-flight stream suffixes, and the (new)
  buffer has no false negatives at *every* cursor position, driven one
  chunk-tick at a time;
* **buffer overflow forces an early settle** — a batch larger than the
  buffer takes the forced path (settle + direct table insert) and
  stays exact, and the normal watermark path resumes afterwards;
* **fold edge cases** — settling an empty buffer is a no-op that does
  not count as a settle, and duplicate keys spanning buffer and table
  keep their multiset counts through the two-stream fold (so
  delete-one-copy semantics survive a settle);
* **interruptibility** — a ``data.pipeline`` snapshot taken mid-settle
  restores into a fresh pipeline bit-for-bit and keeps deduplicating.
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro import filters
from repro.data.pipeline import DedupPipeline, PipelineConfig
from repro.filters import steady


def _keys(seed, n, lo=0, hi=2**32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi, size=n, dtype=np.int64).astype(np.uint32))


class TestSettleCursorExactness:
    def test_no_false_negatives_at_every_cursor_position(self):
        """Drive the drain one chunk-tick at a time; at every cursor the
        settled prefix, both stream suffixes, and fresh buffered keys
        must all answer MAY-CONTAIN."""
        cfg, st = filters.make("steady_qf", q=10, r=16, buf_q=7, chunk=32)
        old = _keys(0, 600)
        st = filters.insert(cfg, st, old)
        st = steady.settle_all(cfg, st)
        buffered = _keys(1, 64, lo=2**31)
        st = filters.insert(cfg, st, buffered)
        st = steady._open_settle(cfg, st)  # arm: table + buffer -> streams
        steps = 0
        while bool((st.cursor < st.src_n) | (st.bcursor < st.bsrc_n)):
            st = steady._drain(cfg, st, 1)
            assert bool(filters.contains(cfg, st, old).all()), f"tick {steps}"
            assert bool(filters.contains(cfg, st, buffered).all()), f"tick {steps}"
            steps += 1
        assert steps >= 5  # actually chunked, not one big pass
        s = filters.stats(cfg, st)
        assert int(s["n"]) == 600 + 64
        assert not bool(s["overflow"])

    def test_inserts_during_drain_stay_exact(self):
        """Writer races the drain: keys inserted while a settle is open
        land in the fresh buffer and must be visible immediately."""
        cfg, st = filters.make(
            "steady_qf", q=10, r=16, buf_q=7, chunk=32, settle_load=0.3
        )
        seen = []
        for i in range(15):
            b = _keys(100 + i, 48)
            seen.append(np.asarray(b))
            st = filters.insert(cfg, st, b)
            allk = jnp.asarray(np.concatenate(seen))
            assert bool(filters.contains(cfg, st, allk).all()), f"batch {i}"
        s = filters.stats(cfg, st)
        assert int(s["n"]) == 15 * 48
        assert int(s["settles"]) >= 2  # the watermark actually tripped
        assert not bool(s["overflow"])


class TestForcedEarlySettle:
    def test_oversized_batch_forces_settle_and_stays_exact(self):
        cfg, st = filters.make("steady_qf", q=12, r=18, buf_q=8, chunk=64)
        cap = cfg.buf.capacity
        big = _keys(2, cap + 200)  # cannot fit the buffer: forced path
        st = filters.insert(cfg, st, big)
        assert bool(filters.contains(cfg, st, big).all())
        s = filters.stats(cfg, st)
        assert int(s["n"]) == cap + 200
        assert int(s["buffered"]) == 0  # landed in the table, not the buffer
        assert not bool(s["overflow"])
        # the normal watermark path resumes after a forced insert
        more = [_keys(3 + i, 64) for i in range(6)]
        for b in more:
            st = filters.insert(cfg, st, b)
        assert bool(filters.contains(cfg, st, jnp.concatenate([big] + more)).all())
        assert int(filters.stats(cfg, st)["n"]) == cap + 200 + 6 * 64

    def test_forced_mid_settle_folds_pending_streams(self):
        """A forced insert arriving while a settle is half-drained must
        fold BOTH pending stream suffixes before the direct insert."""
        cfg, st = filters.make("steady_qf", q=10, r=16, buf_q=7, chunk=16)
        old = _keys(4, 500)
        st = filters.insert(cfg, st, old)
        st = steady.settle_all(cfg, st)
        mid = _keys(5, 64, lo=2**31)
        st = filters.insert(cfg, st, mid)
        st = steady._open_settle(cfg, st)
        st = steady._drain(cfg, st, 1)  # leave the settle half-done
        assert bool((st.cursor < st.src_n) | (st.bcursor < st.bsrc_n))
        big = _keys(6, cfg.buf.capacity + 50)
        st = filters.insert(cfg, st, big)
        for part in (old, mid, big):
            assert bool(filters.contains(cfg, st, part).all())
        assert int(filters.stats(cfg, st)["n"]) == 500 + 64 + cfg.buf.capacity + 50


class TestFoldEdgeCases:
    def test_settle_of_empty_buffer_is_a_counted_noop(self):
        """settle_all on an idle filter changes nothing and does NOT
        bump the settles counter (no work was pending)."""
        cfg, st = filters.make("steady_qf", q=10, r=16, buf_q=7)
        keys = _keys(7, 80)  # fits the buffer: the fold below is real
        st = filters.insert(cfg, st, keys)
        st = steady.settle_all(cfg, st)
        before = filters.stats(cfg, st)
        assert int(before["settles"]) >= 1  # the buffered fold counted
        st = steady.settle_all(cfg, st)  # nothing buffered, nothing pending
        after = filters.stats(cfg, st)
        assert int(after["n"]) == int(before["n"]) == 80
        assert int(after["settles"]) == int(before["settles"])
        assert bool(filters.contains(cfg, st, keys).all())

    def test_duplicates_spanning_buffer_and_table_keep_multiset_counts(self):
        """One copy settled into the table + one copy still buffered:
        the fold must keep BOTH, so delete-one-copy leaves a hit and a
        second delete removes it."""
        cfg, st = filters.make("steady_qf", q=10, r=16, buf_q=7)
        dup = _keys(8, 50)
        st = filters.insert(cfg, st, dup)
        st = steady.settle_all(cfg, st)  # first copies now in the table
        st = filters.insert(cfg, st, dup)  # second copies in the buffer
        st = steady.settle_all(cfg, st)  # fold: table-stream meets dups
        assert int(filters.stats(cfg, st)["n"]) == 100
        st = filters.delete(cfg, st, dup)
        assert bool(filters.contains(cfg, st, dup).all()), "second copies lost"
        assert int(filters.stats(cfg, st)["n"]) == 50
        st = filters.delete(cfg, st, dup)
        assert int(filters.stats(cfg, st)["n"]) == 0

    def test_merge_of_two_steady_filters_is_exact(self):
        cfg, sa = filters.make("steady_qf", q=10, r=16, buf_q=7)
        _, sb = filters.make("steady_qf", q=10, r=16, buf_q=7)
        ka, kb = _keys(9, 300), _keys(10, 300, lo=2**31)
        sa = filters.insert(cfg, sa, ka)
        sb = filters.insert(cfg, sb, kb)  # sb still partly buffered
        sm = filters.by_cfg(cfg).merge(cfg, sa, sb)
        assert bool(filters.contains(cfg, sm, jnp.concatenate([ka, kb])).all())
        assert int(filters.stats(cfg, sm)["n"]) == 600


class TestPipelineSnapshotMidSettle:
    def test_snapshot_restore_mid_settle_roundtrips_and_resumes(self):
        """A checkpoint taken while the dedup filter is mid-drain must
        restore bit-for-bit (every stream plane and cursor is a pytree
        leaf) and keep deduplicating from that exact point."""
        pcfg = PipelineConfig(
            dedup_family="steady_qf",
            dedup_ram_q=10,
            dedup_p=26,
            dedup_chunk=32,
            seq_len=64,
            batch_size=2,
            seed=3,
        )
        pipe = DedupPipeline(pcfg)
        ids0, _ = pipe.corpus.batch(500)
        pipe._dedup(ids0)
        # quiesce (an insert-opened settle may be in flight), buffer a
        # fresh batch, then arm a settle and half-drain it so the
        # snapshot is mid-stream
        fcfg = pipe.filter_cfg
        pipe.filter_state = steady.settle_all(fcfg, pipe.filter_state)
        extra = _keys(11, 64, lo=2**31)
        pipe.filter_state = filters.insert(fcfg, pipe.filter_state, extra)
        pipe.filter_state = steady._open_settle(fcfg, pipe.filter_state)
        pipe.filter_state = steady._drain(fcfg, pipe.filter_state, 1)
        st = pipe.filter_state
        assert bool((st.cursor < st.src_n) | (st.bcursor < st.bsrc_n))
        snap = pipe.snapshot()

        fresh = DedupPipeline(pcfg)
        fresh.restore(snap)
        for a, b in zip(
            jax.tree_util.tree_leaves(pipe.filter_state),
            jax.tree_util.tree_leaves(fresh.filter_state),
        ):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        # the restored filter still knows everything the original saw...
        kept = np.unique(ids0)
        assert bool(
            filters.contains(fresh.filter_cfg, fresh.filter_state, jnp.asarray(kept)).all()
        )
        assert bool(filters.contains(fresh.filter_cfg, fresh.filter_state, extra).all())
        # ...and a replay of the same documents dedups them all away
        keep_again = fresh._dedup(ids0)
        assert not keep_again.any()
        # and the resumed settle drains to the exact population
        fresh.filter_state = steady.settle_all(fresh.filter_cfg, fresh.filter_state)
        s = filters.stats(fresh.filter_cfg, fresh.filter_state)
        assert int(s["n"]) == len(kept) + extra.shape[0]
        assert not bool(s["overflow"])
