"""Incremental (amortized) resize + watermark auto-shrink (PR 4 tentpole).

Pins the three claims that make the paper's "don't thrash" growth story
real end-to-end:

* **migration-in-flight semantics** — membership over old, fresh, and
  in-transit keys has no false negatives at *every* cursor position,
  the chunked left-to-right build reproduces ``build_sorted``
  bit-for-bit, and a settled migration answers exactly like a filter
  built statically at the final size;
* **interruptibility** — a ``data.pipeline`` snapshot taken
  mid-migration restores into a fresh pipeline and the migration
  resumes from its cursor (and keeps deduplicating correctly);
* **auto-shrink** — every family binds the ``needs_shrink``/``shrink``
  protocol; the low watermark's hysteresis band keeps ``auto_scale``
  from thrashing between grow and shrink around a boundary.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import filters
from repro.core import quotient_filter as qf
from repro.filters import incremental_resize as ir
from repro.kernels import ops as kops


def _keys(seed, n, lo=0, hi=2**31):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi, size=n, dtype=np.int64).astype(np.uint32))


class TestMigrationInFlight:
    def test_no_false_negatives_at_every_cursor_position(self):
        """Acceptance: old keys, fresh keys, and the in-transit chunk all
        answer MAY-CONTAIN at every step of the drain."""
        cfg, st = filters.make("qf", q=10, r=14)
        old = _keys(0, cfg.core.capacity)
        st = filters.insert(cfg, st, old)
        mcfg, ms = ir.begin(cfg, st, chunk=96)  # prime-ish: cursor hits
        fresh = []  # every offset against the run structure
        steps = 0
        while not bool(ir.migration_done(mcfg, ms)):
            batch = _keys(1000 + steps, 16, lo=2**31, hi=2**32)
            fresh.append(batch)
            ms = filters.insert(mcfg, ms, batch)
            assert bool(filters.contains(mcfg, ms, old).all()), f"step {steps}"
            for b in fresh:
                assert bool(filters.contains(mcfg, ms, b).all()), f"step {steps}"
            steps += 1
        assert steps >= 7  # actually amortized, not one big pass
        fcfg, fst = ir.finish(mcfg, ms)
        assert fcfg.q == cfg.q + 1
        assert bool(filters.contains(fcfg, fst, old).all())
        for b in fresh:
            assert bool(filters.contains(fcfg, fst, b).all())
        assert not bool(filters.stats(fcfg, fst)["overflow"])

    def test_settled_migration_matches_static_filter_exactly(self):
        """QF fingerprints are split-invariant, so the migrated filter
        must agree with a statically built one on hits AND misses."""
        cfg, st = filters.make("qf", q=9, r=15)
        old = _keys(2, cfg.core.capacity)
        st = filters.insert(cfg, st, old)
        mcfg, ms = ir.begin(cfg, st, chunk=64)
        fresh = _keys(3, 256, lo=2**31, hi=2**32)
        for i in range(0, 256, 32):
            ms = filters.insert(mcfg, ms, fresh[i : i + 32])
        fcfg, fst = ir.finish(mcfg, ms)
        scfg, sst = filters.make("qf", q=fcfg.q, r=fcfg.r)
        sst = filters.insert(scfg, sst, jnp.concatenate([old, fresh]))
        probes = jnp.concatenate([old[:512], fresh, _keys(4, 4096)])
        assert bool(
            (
                filters.contains(fcfg, fst, probes)
                == filters.contains(scfg, sst, probes)
            ).all()
        )
        assert int(filters.stats(fcfg, fst)["n"]) == old.shape[0] + 256

    def test_build_chunk_reproduces_build_sorted_bit_for_bit(self):
        """The carried-scan chunk append IS build_sorted of the prefix."""
        cfg = qf.QFConfig(q=8, r=10, slack=128)
        keys = _keys(5, 150)
        fq, fr = qf.fingerprints(cfg, keys)
        fq, fr = qf._pad_sort(fq, fr, jnp.ones((150,), jnp.bool_))
        want = qf.build_sorted(cfg, fq, fr, 150)
        state = qf.empty(cfg)
        last_pos = jnp.full((), -1, jnp.int32)
        last_fq = jnp.full((), -1, jnp.int32)
        cursor = 0
        for size in (1, 37, 2, 64, 46):  # ragged chunk boundaries
            chunk_q = fq[cursor : cursor + size]
            chunk_r = fr[cursor : cursor + size]
            state, last_pos, last_fq = kops.build_chunk(
                cfg, state, chunk_q, chunk_r, size, last_pos, last_fq
            )
            cursor += size
        for a, b in zip(want, state):
            assert bool(jnp.array_equal(a, b))

    def test_build_span_matches_chunked_build_at_every_cursor(self):
        """One fused span append (the PR 7 finish-time drain) must be
        bit-identical to the sequential chunk moves it replaces, from
        EVERY chunk-aligned migration cursor, in both the xla lowering
        and the interpreted Pallas kernel."""
        cfg = qf.QFConfig(q=8, r=10, slack=128)
        n, C = 200, 32
        keys = _keys(50, n)
        fq, fr = qf.fingerprints(cfg, keys)
        fq, fr = qf._pad_sort(fq, fr, jnp.ones((n,), jnp.bool_))
        want = qf.build_sorted(cfg, fq, fr, n)
        for mode in ("xla", "interpret"):
            state = qf.empty(cfg)
            last_pos = jnp.full((), -1, jnp.int32)
            last_fq = jnp.full((), -1, jnp.int32)
            for cursor in range(0, n, C):
                # one fused span drains everything past this cursor...
                drained, _, _ = kops.build_span(
                    cfg,
                    state,
                    fq[cursor:],
                    fr[cursor:],
                    jnp.int32(n - cursor),
                    last_pos,
                    last_fq,
                    mode=mode,
                )
                for name, a, b in zip(want._fields, want, drained):
                    assert bool(jnp.array_equal(a, b)), (mode, cursor, name)
                # ...while the per-chunk path advances the cursor itself
                state, last_pos, last_fq = kops.build_chunk(
                    cfg,
                    state,
                    fq[cursor : cursor + C],
                    fr[cursor : cursor + C],
                    jnp.int32(min(C, n - cursor)),
                    last_pos,
                    last_fq,
                )

    def test_finish_multi_chunk_drain_matches_stepwise(self):
        """finish()'s single build_span drain over many pending chunks
        must produce the same planes as advancing chunk by chunk."""
        cfg, st = filters.make("qf", q=9, r=15)
        st = filters.insert(cfg, st, _keys(60, cfg.core.capacity))
        mcfg, ms = ir.begin(cfg, st, chunk=64)
        ms = filters.insert(mcfg, ms, _keys(61, 16, lo=2**31, hi=2**32))
        assert not bool(ir.migration_done(mcfg, ms))  # many chunks pending
        ms_ref = ms
        while not bool(ir.migration_done(mcfg, ms_ref)):
            ms_ref = ir._advance(mcfg, ms_ref)  # steps=1: chunk at a time
        fcfg, fst = ir.finish(mcfg, ms)  # one fused span drain
        fcfg_ref, fst_ref = ir.finish(mcfg, ms_ref)
        assert fcfg == fcfg_ref
        for name, a, b in zip(fst._fields, fst, fst_ref):
            assert bool(jnp.array_equal(a, b)), name

    def test_io_charged_per_chunk(self):
        cfg, st = filters.make("qf", q=9, r=15)
        st = filters.insert(cfg, st, _keys(6, cfg.core.capacity))
        mcfg, ms = ir.begin(cfg, st, chunk=128)
        for i in range(3):
            ms = filters.insert(mcfg, ms, _keys(7 + i, 16, lo=2**31, hi=2**32))
        s = filters.stats(mcfg, ms)
        assert int(s["migrate_chunks"]) == 3
        assert int(s["resizes"]) == 1
        # 3 chunks of 128 entries, charged at the old/new slot widths
        assert float(s["seq_read_bytes"]) == pytest.approx(
            3 * 128 * mcfg.src.core.bits_per_slot / 8
        )
        assert float(s["seq_write_bytes"]) == pytest.approx(
            3 * 128 * mcfg.dst.core.bits_per_slot / 8
        )

    def test_buffer_full_trips_settle_predicate(self):
        """Fresh inserts outrunning the drain must flag needs_settle
        before the side buffer overflows (auto_scale finishes early)."""
        cfg, st = filters.make("qf", q=12, r=12)
        st = filters.insert(cfg, st, _keys(9, cfg.core.capacity))
        mcfg, ms = ir.begin(cfg, st, chunk=64, buf_q=8)
        assert not bool(ir.needs_settle(mcfg, ms))
        big = _keys(10, mcfg.buf.core.capacity + 64, lo=2**31, hi=2**32)
        ms = filters.insert(mcfg, ms, big)
        assert bool(ir.needs_settle(mcfg, ms))
        assert not bool(ir.migration_done(mcfg, ms))
        fcfg, fst = ir.finish(mcfg, ms)  # early settle drains + folds
        assert bool(filters.contains(fcfg, fst, big).all())
        assert not bool(filters.stats(fcfg, fst)["overflow"])

    def test_auto_scale_drives_migration_end_to_end(self):
        cfg, st = filters.make("qf", q=8, r=16)
        seen = []
        for i in range(40):
            b = _keys(20 + i, 64)
            seen.append(b)
            cfg, st = filters.auto_scale(cfg, st, b, chunk=256)
        migrated = ir.is_migrating(cfg)
        cfg, st = filters.settle(cfg, st)
        assert cfg.q > 8  # grew at least once on the way
        for b in seen:
            assert bool(filters.contains(cfg, st, b).all())
        assert not bool(filters.stats(cfg, st)["overflow"])
        assert isinstance(migrated, bool)

    def test_merge_streams_matches_sort(self):
        rng = np.random.default_rng(11)
        for na, nb in ((0, 5), (7, 0), (33, 17), (64, 64)):
            la, lb = na + 9, nb + 4
            aq = np.sort(rng.integers(0, 200, na)).astype(np.int32)
            bq = np.sort(rng.integers(0, 200, nb)).astype(np.int32)
            ar = rng.integers(0, 2**16, na).astype(np.uint32)
            br = rng.integers(0, 2**16, nb).astype(np.uint32)
            # remainders must be sorted within equal quotients
            aq_j = jnp.concatenate(
                [jnp.asarray(aq), jnp.full((la - na,), qf.INT32_MAX, jnp.int32)]
            )
            bq_j = jnp.concatenate(
                [jnp.asarray(bq), jnp.full((lb - nb,), qf.INT32_MAX, jnp.int32)]
            )
            ar_j = jnp.concatenate(
                [jnp.asarray(ar), jnp.full((la - na,), qf.UINT32_MAX, jnp.uint32)]
            )
            br_j = jnp.concatenate(
                [jnp.asarray(br), jnp.full((lb - nb,), qf.UINT32_MAX, jnp.uint32)]
            )
            aq_j, ar_j = qf._pad_sort(aq_j, ar_j, jnp.arange(la) < na)
            bq_j, br_j = qf._pad_sort(bq_j, br_j, jnp.arange(lb) < nb)
            mq, mr = qf.merge_streams(aq_j, ar_j, na, bq_j, br_j, nb)
            wq, wr = qf._pad_sort(
                jnp.concatenate([aq_j, bq_j]),
                jnp.concatenate([ar_j, br_j]),
                jnp.concatenate([jnp.arange(la) < na, jnp.arange(lb) < nb]),
            )
            assert bool(jnp.array_equal(mq, wq)) and bool(jnp.array_equal(mr, wr))

    def test_facade_rejects_delete_mid_migration(self):
        cfg, st = filters.make("qf", q=8, r=16)
        st = filters.insert(cfg, st, _keys(12, cfg.core.capacity))
        mcfg, ms = ir.begin(cfg, st)
        assert not filters.supports(mcfg, "delete")
        with pytest.raises(NotImplementedError):
            filters.delete(mcfg, ms, _keys(13, 8))


class TestPipelineMigrationSnapshot:
    def test_snapshot_restore_mid_migration_resumes(self):
        """Acceptance: interrupting a migration (snapshot/restore in
        data/pipeline.py) resumes correctly."""
        from repro.data.pipeline import DedupPipeline, PipelineConfig

        cfgp = PipelineConfig(
            seq_len=64,
            batch_size=2,
            duplicate_fraction=0.0,
            seed=21,
            dedup_family="qf",
            dedup_ram_q=8,
            dedup_p=28,
            dedup_chunk=64,
        )
        pipe = DedupPipeline(cfgp)
        rng = np.random.default_rng(5)
        ingested = []
        # ingest until a migration is actually in flight
        for _ in range(64):
            ids = rng.integers(0, 2**32, 48, dtype=np.uint64).astype(np.uint32)
            ingested.append(ids)
            pipe._dedup(ids)
            if ir.is_migrating(pipe.filter_cfg):
                break
        assert ir.is_migrating(pipe.filter_cfg), "never entered migration"
        cursor_at_snap = int(pipe.filter_state.cursor)
        snap = pipe.snapshot()

        pipe2 = DedupPipeline(cfgp)
        pipe2.restore(snap)
        assert ir.is_migrating(pipe2.filter_cfg)
        assert int(pipe2.filter_state.cursor) == cursor_at_snap
        # everything ingested before the snapshot is recognized as dup
        for ids in ingested:
            assert not pipe2._dedup(ids).any()
        # and the restored pipeline can finish the migration and go on
        for i in range(64):
            ids = rng.integers(0, 2**32, 48, dtype=np.uint64).astype(np.uint32)
            pipe2._dedup(ids)
            if not ir.is_migrating(pipe2.filter_cfg):
                break
        assert not ir.is_migrating(pipe2.filter_cfg)
        assert not bool(
            filters.stats(pipe2.filter_cfg, pipe2.filter_state)["overflow"]
        )

    def test_mismatched_snapshot_still_refused(self):
        from repro.data.pipeline import DedupPipeline, PipelineConfig

        a = PipelineConfig(dedup_family="qf", dedup_ram_q=8, dedup_p=28)
        b = PipelineConfig(dedup_family="qf", dedup_ram_q=9, dedup_p=28)
        pa, pb = DedupPipeline(a), DedupPipeline(b)
        snap = pa.snapshot()
        snap["filter_leaves"] = snap["filter_leaves"][:-1]  # corrupt
        with pytest.raises(ValueError):
            pb.restore(snap)


class TestAutoShrink:
    def test_every_family_answers_shrink_through_facade(self):
        for name in filters.names():
            assert filters.supports(name, "needs_shrink"), name
            assert filters.supports(name, "shrink"), name

    def test_qf_shrink_roundtrip_improves_fp_budget(self):
        cfg, st = filters.make("qf", q=10, r=14)
        keys = _keys(30, 120)
        st = filters.insert(cfg, st, keys)
        assert bool(filters.needs_shrink(cfg, st))  # 120 < 0.4 * cap(q=9)
        new_cfg, new_st = filters.shrink(cfg, st)
        assert (new_cfg.q, new_cfg.r) == (9, 15)  # remainder bit comes back
        assert bool(filters.contains(new_cfg, new_st, keys).all())
        assert int(filters.stats(new_cfg, new_st)["n"]) == 120
        assert not bool(filters.needs_resize(new_cfg, new_st))

    def test_bloom_fold_preserves_membership_and_deletes(self):
        cfg, st = filters.make("bloom", m_bits=1 << 12, k=4, counting=True)
        keys = _keys(31, 200)
        st = filters.insert(cfg, st, keys)
        cfg2, st2 = filters.grow(cfg, st)
        assert bool(filters.needs_shrink(cfg2, st2))
        cfg3, st3 = filters.shrink(cfg2, st2)
        assert cfg3.m_bits == cfg.m_bits
        assert bool(filters.contains(cfg3, st3, keys).all())
        st3 = filters.delete(cfg3, st3, keys[:50])
        assert int(filters.stats(cfg3, st3)["n"]) == 150

    def test_blocked_bloom_fold(self):
        cfg, st = filters.make(
            "blocked_bloom", m_bits=1 << 13, k=4, block_bits=1 << 10
        )
        keys = _keys(32, 100)
        st = filters.insert(cfg, st, keys)
        cfg2, st2 = filters.grow(cfg, st)
        cfg3, st3 = filters.shrink(cfg2, st2)
        assert cfg3.n_blocks == cfg.n_blocks
        assert bool(filters.contains(cfg3, st3, keys).all())

    def test_cascade_pops_empty_levels(self):
        cfg, st = filters.make("cascade", ram_q=7, p=30, fanout=4, levels=1)
        keys = _keys(33, 3000)
        for i in range(0, 3000, 64):
            cfg, st = filters.auto_scale(cfg, st, keys[i : i + 64])
        assert cfg.levels > 1
        st = filters.delete(cfg, st, keys[:2950])
        popped = 0
        while bool(filters.needs_shrink(cfg, st)):
            cfg, st = filters.shrink(cfg, st)
            popped += 1
        assert popped >= 1
        assert bool(filters.contains(cfg, st, keys[2950:]).all())
        assert not bool(filters.needs_resize(cfg, st))

    def test_buffered_disk_shrink_charges_io(self):
        cfg, st = filters.make("buffered_qf", ram_q=7, disk_q=12, p=26)
        keys = _keys(34, 512)  # disk ends well under 0.4 * cap(disk_q=11)
        for i in range(0, 512, 64):
            st = filters.insert(cfg, st, keys[i : i + 64])
        assert bool(filters.needs_shrink(cfg, st))
        before = filters.stats(cfg, st)
        cfg2, st2 = filters.shrink(cfg, st)
        after = filters.stats(cfg2, st2)
        assert cfg2.disk_q == 11
        assert bool(filters.contains(cfg2, st2, keys).all())
        assert int(after["resizes"]) == int(before["resizes"]) + 1
        assert float(after["seq_read_bytes"]) > float(before["seq_read_bytes"])

    def test_hysteresis_no_thrash_around_boundary(self):
        """Oscillating around the high watermark must not flip the
        structure back and forth: after a grow, the shrink watermark
        sits far below the boundary that triggered it."""
        cfg, st = filters.make("qf", q=8, r=16)
        keys = _keys(35, cfg.core.capacity + 32)
        cfg, st = filters.auto_scale(cfg, st, keys, incremental=False)
        assert cfg.q == 9  # grew past the boundary
        transitions = 0
        last_q = cfg.q
        for i in range(12):
            # delete and reinsert a small band around the old boundary
            st = filters.delete(cfg, st, keys[:16])
            cfg, st = filters.auto_scale(cfg, st, keys[:16], incremental=False)
            if cfg.q != last_q:
                transitions += 1
                last_q = cfg.q
        assert transitions == 0  # hysteresis band holds

    def test_sharded_shrink_redistributes_across_devices(self):
        """Halve a 2-shard filter on 2 fake devices (subprocess, as in
        test_distributed) and check membership + static equivalence."""
        from tests.test_distributed import run_with_devices

        out = run_with_devices(
            """
            import numpy as np, jax.numpy as jnp
            from repro import filters

            rng = np.random.default_rng(7)
            keys = jnp.asarray(
                rng.integers(0, 2**31, 512, dtype=np.int64).astype(np.uint32)
            )
            cfg, st = filters.make("sharded_qf", q=12, r=10, n_shards=2)
            st = filters.insert(cfg, st, keys)
            assert bool(filters.needs_shrink(cfg, st))  # 512 < 0.4*cap(q=11)
            new_cfg, new_st = filters.shrink(cfg, st)
            # the exact inverse of grow: half the shards, half the buckets,
            # one remainder bit back
            assert (new_cfg.q, new_cfg.r, new_cfg.n_shards) == (11, 11, 1)
            s = filters.stats(new_cfg, new_st)
            assert int(s["n"]) == 512 and not bool(s["overflow"])
            assert bool(filters.contains(new_cfg, new_st, keys).all())
            # one step of hysteresis: the halved threshold must not retrip
            # (512 > 0.4 * cap(q=10) = 307)
            assert not bool(filters.needs_shrink(new_cfg, new_st))
            scfg, sst = filters.make("sharded_qf", q=11, r=11, n_shards=1)
            sst = filters.insert(scfg, sst, keys)
            probes = jnp.asarray(
                rng.integers(2**31, 2**32, 4096, dtype=np.int64).astype(np.uint32)
            )
            assert bool(
                (
                    filters.contains(new_cfg, new_st, probes)
                    == filters.contains(scfg, sst, probes)
                ).all()
            )
            print("OK")
            """,
            n_devices=2,
        )
        assert "OK" in out


class TestServingCache:
    def test_prefix_cache_grows_incrementally_and_shrinks_after_eviction(self):
        from repro.serve.prefix_cache import PrefixCacheFilter

        pc = PrefixCacheFilter(q=8, r=18, chunk=128)
        rng = np.random.default_rng(40)
        prompts = [rng.integers(0, 1000, 24, dtype=np.int64) for _ in range(700)]
        for i in range(0, 700, 50):
            hits = pc.check_and_insert(np.asarray(prompts[i : i + 50]))
            assert hits.shape == (50,)
        # everything previously inserted must hit (settle may be pending)
        for i in range(0, 700, 100):
            assert pc.check_and_insert(np.asarray(prompts[i : i + 50])).all()
        grown_q = (
            pc.cfg.dst.q if ir.is_migrating(pc.cfg) else pc.cfg.q
        )
        assert grown_q > 8
        # evicting most of the cache lets the low watermark shrink it
        for i in range(0, 650, 50):
            pc.evict(np.asarray(prompts[i : i + 50]))
        assert not ir.is_migrating(pc.cfg)  # evict settles first
        assert pc.cfg.q <= grown_q
