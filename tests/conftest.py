"""Shared test configuration: pinned hypothesis profiles.

CI runs with ``HYPOTHESIS_PROFILE=ci`` (see .github/workflows/ci.yml):
``derandomize=True`` makes every property run the same example
sequence on every build, so the resize round-trip properties in
``tests/test_resize.py`` (and any future property tests) cannot flake
the gate with a fresh random seed.  Local runs keep the randomized
``dev`` profile — that is where new counterexamples get found.
"""

import os

try:
    from hypothesis import settings
except ImportError:  # requirements-dev.txt installs it; degrade quietly
    settings = None

if settings is not None:
    settings.register_profile("ci", derandomize=True, deadline=None)
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
