"""Shared test configuration: pinned hypothesis profiles.

CI runs with ``HYPOTHESIS_PROFILE=ci`` (see .github/workflows/ci.yml):
``derandomize=True`` makes every property run the same example
sequence on every build, so the resize round-trip properties in
``tests/test_resize.py`` (and any future property tests) cannot flake
the gate with a fresh random seed.  Local runs keep the randomized
``dev`` profile — that is where new counterexamples get found.
"""

import os

import jax
import pytest

try:
    from hypothesis import settings
except ImportError:  # requirements-dev.txt installs it; degrade quietly
    settings = None


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    """Drop JAX's compiled-program caches between test modules.

    A full-suite run accumulates hundreds of compiled programs across
    modules; on CPU that pile-up can segfault a later large
    ``lax.switch`` trace (reproducibly at suite scale, never in
    isolation).  Per-module isolation costs some recompilation but
    keeps every module's compile behavior independent of suite order.
    """
    yield
    if hasattr(jax, "clear_caches"):  # jax >= 0.4.9; no-op guard below
        jax.clear_caches()

if settings is not None:
    settings.register_profile("ci", derandomize=True, deadline=None)
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
