"""Unit + property tests for the bulk-parallel quotient filter."""

import numpy as np
import jax.numpy as jnp
import pytest

try:  # property tests degrade to skips without hypothesis (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*a, **k):  # inert decorator stand-ins so the module imports
        return lambda f: f

    settings = given

    class _Anything:
        def __getattr__(self, name):
            return self

        def __call__(self, *a, **k):
            return self

    st = _Anything()

from repro.core import quotient_filter as qf

from reference_qf import PaperQF


def _keys(rng, n, lo=0, hi=2**31):
    return jnp.asarray(rng.integers(lo, hi, size=n, dtype=np.int64).astype(np.uint32))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


CFG = qf.QFConfig(q=10, r=9, slack=512)


class TestBasics:
    def test_empty_contains_nothing(self, rng):
        st_ = qf.empty(CFG)
        assert not bool(qf.contains(CFG, st_, _keys(rng, 100)).any())

    def test_no_false_negatives(self, rng):
        st_ = qf.insert(CFG, qf.empty(CFG), _keys(rng, 700))
        # reuse same rng stream won't reproduce keys; regenerate
        rng2 = np.random.default_rng(0)
        ks = _keys(rng2, 700)
        assert bool(qf.contains(CFG, st_, ks).all())
        assert bool(qf.lookup_exact(CFG, st_, *qf.fingerprints(CFG, ks)).all())
        assert not bool(st_.overflow)

    def test_fp_rate_close_to_theory(self, rng):
        n = 700
        st_ = qf.insert(CFG, qf.empty(CFG), _keys(rng, n))
        probes = _keys(rng, 300_000, lo=2**31, hi=2**32)
        fp = float(qf.contains(CFG, st_, probes).mean())
        expected = n / 2 ** (CFG.q + CFG.r)  # 1 - e^{-n/2^p} ~ n/2^p
        assert fp < 4 * expected + 1e-4
        assert fp > expected / 4

    def test_multiset_duplicates(self, rng):
        ks = _keys(rng, 50)
        st_ = qf.insert(CFG, qf.empty(CFG), jnp.concatenate([ks, ks]))
        assert int(st_.n) == 100
        st_ = qf.delete(CFG, st_, ks)  # removes one copy of each
        assert int(st_.n) == 50
        assert bool(qf.contains(CFG, st_, ks).all())
        st_ = qf.delete(CFG, st_, ks)
        assert int(st_.n) == 0

    def test_extract_build_roundtrip(self, rng):
        st_ = qf.insert(CFG, qf.empty(CFG), _keys(rng, 600))
        fq, fr, n = qf.extract(CFG, st_)
        st2 = qf.build_sorted(CFG, fq, fr, n)
        for a, b in zip(st_, st2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_extract_is_sorted(self, rng):
        st_ = qf.insert(CFG, qf.empty(CFG), _keys(rng, 600))
        fq, fr, n = qf.extract(CFG, st_)
        fqn = np.asarray(fq)[: int(n)]
        frn = np.asarray(fr)[: int(n)]
        comb = fqn.astype(np.int64) * 2**32 + frn
        assert (np.diff(comb) >= 0).all()

    def test_windowed_matches_exact_at_high_load(self, rng):
        cfg = qf.QFConfig(q=10, r=9, slack=512, max_load=0.9)
        ks = _keys(rng, 920)  # ~90% load: long clusters stress the window
        st_ = qf.insert(cfg, qf.empty(cfg), ks)
        probes = jnp.concatenate([ks, _keys(rng, 2000, lo=2**31, hi=2**32)])
        fq, fr = qf.fingerprints(cfg, probes)
        exact = qf.lookup_exact(cfg, st_, fq, fr)
        for window in (16, 64, 256):
            win = qf.lookup(cfg, st_, fq, fr, window)
            np.testing.assert_array_equal(np.asarray(win), np.asarray(exact))


class TestPaperParity:
    """Bulk-parallel build must reproduce the paper's item-at-a-time
    structure bit-for-bit."""

    @pytest.mark.parametrize("n,seed", [(50, 1), (300, 2), (700, 3), (950, 4)])
    def test_structure_matches_paper_insert(self, n, seed):
        rng = np.random.default_rng(seed)
        cfg = qf.QFConfig(q=10, r=8, slack=256, max_load=1.0)
        keys = _keys(rng, n)
        fq, fr = qf.fingerprints(cfg, keys)
        fqn, frn = np.asarray(fq), np.asarray(fr)

        ref = PaperQF(cfg.q, cfg.r, cfg.slack)
        for a, b in zip(fqn, frn):
            ref.insert(int(a), int(b))

        st_ = qf.insert(cfg, qf.empty(cfg), keys)
        rem, occ, shf, con = ref.planes()
        used = np.asarray(st_.occ) | np.asarray(st_.shf)
        np.testing.assert_array_equal(np.asarray(st_.occ), np.asarray(occ, bool))
        np.testing.assert_array_equal(np.asarray(st_.shf), np.asarray(shf, bool))
        np.testing.assert_array_equal(np.asarray(st_.con), np.asarray(con, bool))
        # remainders compare only on used slots (free slots are don't-care)
        np.testing.assert_array_equal(
            np.asarray(st_.rem)[used], np.asarray(rem, np.uint32)[used]
        )

    def test_contains_matches_paper(self):
        rng = np.random.default_rng(7)
        cfg = qf.QFConfig(q=8, r=6, slack=256)
        keys = _keys(rng, 150)
        fq, fr = map(np.asarray, qf.fingerprints(cfg, keys))
        ref = PaperQF(cfg.q, cfg.r, cfg.slack)
        for a, b in zip(fq, fr):
            ref.insert(int(a), int(b))
        st_ = qf.insert(cfg, qf.empty(cfg), keys)
        probes = _keys(rng, 3000, lo=0, hi=2**32)
        pq, pr = map(np.asarray, qf.fingerprints(cfg, probes))
        got = np.asarray(qf.lookup(cfg, st_, jnp.asarray(pq), jnp.asarray(pr)))
        want = np.array([ref.contains(int(a), int(b)) for a, b in zip(pq, pr)])
        np.testing.assert_array_equal(got, want)


class TestMergeResize:
    def test_merge_equals_union(self, rng):
        a, b = _keys(rng, 300), _keys(rng, 300, lo=2**31, hi=2**32)
        cfg = qf.QFConfig(q=10, r=10, slack=512)
        sa = qf.insert(cfg, qf.empty(cfg), a)
        sb = qf.insert(cfg, qf.empty(cfg), b)
        big = qf.QFConfig(q=11, r=9, slack=512)
        sm = qf.merge(big, cfg, cfg, sa, sb)
        assert int(sm.n) == 600
        both = jnp.concatenate([a, b])
        assert bool(qf.contains(big, sm, both).all())
        # merged filter fingerprints == direct-build fingerprints
        direct = qf.insert(big, qf.empty(big), both)
        for x, y in zip(sm, direct):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_resize_preserves_fingerprints(self, rng):
        cfg = qf.QFConfig(q=10, r=10, slack=512)
        ks = _keys(rng, 700)
        st_ = qf.insert(cfg, qf.empty(cfg), ks)
        up_cfg, up = qf.resize(cfg, st_, 12)
        assert up_cfg.r == 8
        assert bool(qf.contains(up_cfg, up, ks).all())
        down_cfg, down = qf.resize(up_cfg, up, 10)
        for x, y in zip(down, st_):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_multi_merge(self, rng):
        cfg = qf.QFConfig(q=9, r=11, slack=256)
        parts, all_keys = [], []
        for i in range(4):
            ks = _keys(rng, 150, lo=i * 2**28, hi=(i + 4) * 2**28)
            all_keys.append(ks)
            parts.append((cfg, qf.insert(cfg, qf.empty(cfg), ks)))
        out_cfg = qf.QFConfig(q=11, r=9, slack=512)
        merged = qf.multi_merge(out_cfg, parts)
        assert int(merged.n) == 600
        assert bool(qf.contains(out_cfg, merged, jnp.concatenate(all_keys)).all())


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="property tests need hypothesis")
class TestProperties:
    @settings(max_examples=25, deadline=None)
    @given(
        ops=st.lists(
            st.tuples(st.booleans(), st.integers(0, 2**32 - 1)),
            min_size=1,
            max_size=120,
        )
    )
    def test_never_false_negative_under_interleaving(self, ops):
        """Any interleaving of inserts/deletes: a key inserted more times
        than deleted must be reported present."""
        cfg = qf.QFConfig(q=8, r=10, slack=256, max_load=1.0)
        st_ = qf.empty(cfg)
        counts: dict[int, int] = {}
        for is_delete, key in ops:
            arr = jnp.asarray([key], jnp.uint32)
            if is_delete and counts.get(key, 0) > 0:
                st_ = qf.delete(cfg, st_, arr)
                counts[key] -= 1
            elif not is_delete:
                st_ = qf.insert(cfg, st_, arr)
                counts[key] = counts.get(key, 0) + 1
        live = [k for k, c in counts.items() if c > 0]
        assert int(st_.n) == sum(counts.values())
        if live:
            got = qf.contains(cfg, st_, jnp.asarray(live, jnp.uint32))
            assert bool(got.all())

    @settings(max_examples=15, deadline=None)
    @given(
        keys=st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=200),
        q=st.integers(6, 12),
    )
    def test_roundtrip_any_shape(self, keys, q):
        cfg = qf.QFConfig(q=q, r=10, slack=512, max_load=1.0)
        arr = jnp.asarray(keys, jnp.uint32)
        st_ = qf.insert(cfg, qf.empty(cfg), arr)
        fq, fr, n = qf.extract(cfg, st_)
        st2 = qf.build_sorted(cfg, fq, fr, n)
        for a, b in zip(st_, st2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert bool(qf.contains(cfg, st_, arr).all())
