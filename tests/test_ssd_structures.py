"""BQF / CF / BF-variant behaviour + I/O-schedule accounting tests."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bloom, quotient_filter as qf
from repro.core.buffered_qf import BufferedQuotientFilter
from repro.core.cascade_filter import CascadeFilter
from repro.core.bf_variants import (
    BufferedBloomFilter,
    ElevatorBloomFilter,
    ForestBloomFilter,
)
from repro.core.cost_model import PAPER_SSD, modeled_seconds


def _keys(rng, n, lo=0, hi=2**31):
    return jnp.asarray(rng.integers(lo, hi, size=n, dtype=np.int64).astype(np.uint32))


@pytest.fixture
def rng():
    return np.random.default_rng(42)


class TestBQF:
    def test_membership_and_flushes(self, rng):
        bqf = BufferedQuotientFilter(qf.QFConfig(q=9, r=15), qf.QFConfig(q=13, r=11))
        ks = _keys(rng, 5000)
        for i in range(0, 5000, 250):
            bqf.insert(ks[i : i + 250])
        assert bqf.count == 5000
        assert bqf.io.flushes >= 10
        assert bool(bqf.lookup(ks).all())

    def test_lookup_io_short_circuits(self, rng):
        bqf = BufferedQuotientFilter(qf.QFConfig(q=9, r=15), qf.QFConfig(q=13, r=11))
        ks = _keys(rng, 1000)
        bqf.insert(ks)
        bqf.flush()
        before = bqf.io.snapshot()
        bqf.lookup(ks[:100])
        # all 100 missed RAM (it was just flushed) -> 100 page reads
        assert bqf.io.delta(before).rand_page_reads == 100

    def test_flush_cost_is_sequential(self, rng):
        bqf = BufferedQuotientFilter(qf.QFConfig(q=9, r=15), qf.QFConfig(q=13, r=11))
        bqf.insert(_keys(rng, 300))  # below the 0.75 * 512 flush threshold
        bqf.flush()
        assert bqf.io.seq_write_bytes == bqf.disk_cfg.size_bytes
        assert bqf.io.rand_page_writes == 0  # the whole point of the paper


class TestCascade:
    def test_membership_across_merges(self, rng):
        cf = CascadeFilter(ram_q=8, p=26, fanout=2)
        ks = _keys(rng, 4000)
        for i in range(0, 4000, 200):
            cf.insert(ks[i : i + 200])
        assert cf.count == 4000
        assert cf.io.merges > 0
        assert bool(cf.lookup(ks).all())

    def test_fp_rate(self, rng):
        cf = CascadeFilter(ram_q=8, p=26, fanout=2)
        for i in range(10):
            cf.insert(_keys(rng, 400))
        fp = float(cf.lookup(_keys(rng, 100_000, lo=2**31, hi=2**32)).mean())
        assert fp < 8 * 4000 / 2**26 + 1e-4

    @pytest.mark.parametrize("fanout", [2, 4, 16])
    def test_fanout_level_count(self, rng, fanout):
        cf = CascadeFilter(ram_q=8, p=26, fanout=fanout)
        for i in range(0, 6000, 200):
            cf.insert(_keys(rng, 200))
        # higher fanout => fewer levels (paper §5.3)
        import math

        expected_max = math.ceil(math.log(6000 / cf.q0_cfg.capacity, fanout)) + 1
        assert cf.n_nonempty_levels() <= expected_max

    def test_insert_io_beats_bqf_at_scale(self, rng):
        """The paper's asymptotic claim: CF writes O(log(n/M)/B) per
        insert vs BQF's O(n/(MB)) — at a large filter:RAM ratio the CF
        moves fewer bytes."""
        ram_q, p, n = 7, 26, 12_000
        cf = CascadeFilter(ram_q=ram_q, p=p, fanout=2)
        bqf = BufferedQuotientFilter(
            qf.QFConfig(q=ram_q, r=p - ram_q), qf.QFConfig(q=14, r=p - 14)
        )
        rng2 = np.random.default_rng(7)
        for i in range(0, n, 96):
            batch = _keys(rng2, 96)
            cf.insert(batch)
            bqf.insert(batch)
        cf_bytes = cf.io.seq_read_bytes + cf.io.seq_write_bytes
        bqf_bytes = bqf.io.seq_read_bytes + bqf.io.seq_write_bytes
        assert cf_bytes < bqf_bytes

    def test_deamortized_accounting_smooth(self, rng):
        cf = CascadeFilter(ram_q=8, p=26, fanout=2, deamortize=True)
        for i in range(0, 3000, 100):
            cf.insert(_keys(rng, 100))
        # merges happened but some of their I/O is still pending
        assert cf.io.merges > 0


class TestBFVariants:
    def test_ebf(self, rng):
        cfg = bloom.BloomConfig(m_bits=1 << 18, k=6)
        ebf = ElevatorBloomFilter(cfg, buffer_capacity_bits=4096)
        ks = _keys(rng, 3000)
        for i in range(0, 3000, 500):
            ebf.insert(ks[i : i + 500])
        assert bool(ebf.lookup(ks).all())
        assert ebf.io.flushes > 0 and ebf.io.rand_page_writes > 0

    def test_bbf_localized_lookup_io(self, rng):
        cfg = bloom.BloomConfig(m_bits=1 << 24, k=12)
        bbf = BufferedBloomFilter(cfg, ram_bytes=1 << 14)
        ks = _keys(rng, 2000)
        bbf.insert(ks)
        before = bbf.io.snapshot()
        bbf.lookup(ks[:100])  # successful lookups: ~k pages each (paper §5.2)
        reads = bbf.io.delta(before).rand_page_reads
        assert 100 * 4 <= reads <= 100 * 12

    def test_fbf_layers_and_membership(self, rng):
        fbf = ForestBloomFilter(
            bits_per_element=12.0, ram_bytes=1024, total_elements=8000
        )
        ks = _keys(rng, 4000)
        for i in range(0, 4000, 250):
            fbf.insert(ks[i : i + 250])
        assert len(fbf.layers) >= 2
        assert bool(fbf.lookup(ks).all())

    def test_counting_bloom_delete(self, rng):
        cfg = bloom.BloomConfig(m_bits=1 << 16, k=6, counting=True)
        bits = bloom.insert(cfg, bloom.empty(cfg), _keys(rng, 500))
        rng2 = np.random.default_rng(42)
        ks = _keys(rng2, 500)
        bits = bloom.counting_delete(cfg, bits, ks[:250])
        assert bool(bloom.lookup(cfg, bits, ks[250:]).all())


class TestCostModel:
    def test_paper_constants(self):
        from repro.core.cost_model import IOLog

        log = IOLog(rand_page_reads=3200, rand_page_writes=0)
        assert abs(modeled_seconds(log, PAPER_SSD) - 1.0) < 1e-9
        log = IOLog(seq_write_bytes=int(109e6))
        assert abs(modeled_seconds(log, PAPER_SSD) - 1.0) < 1e-9
