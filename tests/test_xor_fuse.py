"""Frozen tier tests: binary-fuse core, xor_fuse family, cascade
demotion, the 3-gather Pallas kernel, capability errors, and the
cost-model-vs-IOCounters validation."""

import numpy as np
import jax.numpy as jnp
import pytest

try:  # property tests degrade to skips without hypothesis (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*a, **k):  # inert decorator stand-ins so the module imports
        return lambda f: f

    settings = given

    class _Anything:
        def __getattr__(self, name):
            return self

        def __call__(self, *a, **k):
            return self

    st = _Anything()

from repro import filters
from repro.core import cost_model
from repro.core import fuse_filter as fuse
from repro.filters import xor_fuse
from repro.filters.registry import UnsupportedOpError
from repro.kernels import ops as kernel_ops


def _keys(seed, n, lo=0, hi=2**31):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi, size=n, dtype=np.int64).astype(np.uint32))


FROZEN_SPEC = dict(ram_q=8, p=26, fanout=2, levels=4, frozen_below=1)


def _fill(cfg, st, keys, chunk=128):
    for i in range(0, keys.shape[0], chunk):
        st = filters.insert(cfg, st, keys[i : i + chunk])
    return st


# ---------------------------------------------------------------------------
# Core: peel-construct -> probe round trips
# ---------------------------------------------------------------------------


class TestFuseCore:
    @pytest.mark.parametrize("n", [1, 7, 100, 1000, 5000])
    def test_freeze_roundtrip_no_false_negatives(self, n):
        cfg = fuse.make_config(max(n, 1), p=26, seed=n)
        keys = _keys(n, n)
        st = fuse.freeze_keys(cfg, keys)
        assert bool(fuse.contains(cfg, st, keys).all())
        assert int(st.n) == n

    def test_fp_rate_within_bound(self):
        n = 4000
        cfg = fuse.make_config(n, p=26, fp_bits=10)
        st = fuse.freeze_keys(cfg, _keys(1, n))
        absent = _keys(2, 60_000, lo=2**31, hi=2**32)
        rate = float(fuse.contains(cfg, st, absent).mean())
        # 2^-10 target with ~4x slack for a 60k-sample estimate
        assert rate < 4 * 2**-cfg.fp_bits

    def test_duplicate_fingerprints_peel(self):
        # identical keys => identical hyperedges; dedup-before-peel must
        # keep construction feasible and membership exact
        base = _keys(3, 700)
        keys = jnp.concatenate([base, base, base[:123]])
        cfg = fuse.make_config(keys.shape[0], p=26)
        st = fuse.freeze_keys(cfg, keys)
        assert bool(fuse.contains(cfg, st, base).all())
        assert int(st.n) == keys.shape[0]
        assert int(st.n_unique) == 700

    def test_empty_state_contains_nothing(self):
        cfg = fuse.make_config(512, p=26)
        st = fuse.empty(cfg)
        assert not bool(fuse.contains(cfg, st, _keys(4, 512)).any())

    def test_run_reexpansion_is_exact(self):
        cfg = fuse.make_config(1200, p=26)
        keys = _keys(5, 900)
        st = fuse.freeze_keys(cfg, keys)
        fq, fr, n = fuse.extract_run(cfg, st)
        st2 = fuse.freeze(cfg, fq, fr, int(n))
        assert int(st2.n) == 900
        assert bool(fuse.contains(cfg, st2, keys).all())

    def test_capacity_overflow_raises(self):
        cfg = fuse.make_config(100, p=26)
        with pytest.raises(ValueError, match="exceeds frozen capacity"):
            fuse.freeze_keys(cfg, _keys(6, 101))

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="needs hypothesis")
    @given(
        n=st.integers(min_value=1, max_value=600),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        dup=st.integers(min_value=0, max_value=50),
    )
    @settings(max_examples=30, deadline=None)
    def test_property_peel_probe_roundtrip(self, n, seed, dup):
        rng = np.random.default_rng(seed)
        uniq = rng.integers(0, 2**32, size=n, dtype=np.uint64).astype(np.uint32)
        keys = jnp.asarray(np.concatenate([uniq, uniq[: min(dup, n)]]))
        cfg = fuse.make_config(keys.shape[0], p=26, seed=seed & 0xFFFF)
        fst = fuse.freeze_keys(cfg, keys)
        assert bool(fuse.contains(cfg, fst, keys).all())  # no false negatives
        absent = jnp.asarray(
            rng.integers(0, 2**32, size=4096, dtype=np.uint64).astype(np.uint32)
        )
        member = np.isin(np.asarray(absent), np.asarray(keys))
        rate = float(np.asarray(fuse.contains(cfg, fst, absent))[~member].mean())
        assert rate < max(8 * 2**-cfg.fp_bits, 0.01)


# ---------------------------------------------------------------------------
# Pallas kernel vs reference
# ---------------------------------------------------------------------------


class TestFuseKernel:
    @pytest.mark.parametrize("nq", [16, 128, 777, 4096])
    def test_pallas_matches_reference(self, nq):
        cfg = fuse.make_config(3000, p=26, seed=9)
        st = fuse.freeze_keys(cfg, _keys(7, 3000))
        mixed = jnp.concatenate(
            [_keys(7, 3000)[: nq // 2], _keys(8, nq - nq // 2, lo=2**31, hi=2**32)]
        )
        ref = fuse.contains(cfg, st, mixed)
        pal = kernel_ops.fuse_contains(cfg, st, mixed)
        assert bool((ref == pal).all())

    def test_pallas_empty_table(self):
        cfg = fuse.make_config(512, p=26)
        st = fuse.empty(cfg)
        assert not bool(kernel_ops.fuse_contains(cfg, st, _keys(9, 300)).any())

    def test_ref_kernel_oracle_agrees(self):
        # kernels/ref.py is an independent oracle: check it against core
        from repro.kernels.ref import fuse_probe_ref

        cfg = fuse.make_config(2000, p=26)
        st = fuse.freeze_keys(cfg, _keys(10, 2000))
        q = _keys(11, 1024, lo=0, hi=2**32)
        fq, fr = fuse.key_fingerprints(cfg, q)
        p0, p1, p2, fp = fuse.fuse_hash(cfg, fq, fr, st.fuse_seed)
        got = fuse_probe_ref(st.table, p0, p1, p2, fp)
        want = fuse.lookup_fp(cfg, st, fq, fr)
        assert bool((got == want).all())


# ---------------------------------------------------------------------------
# Cascade demotion: demote -> probe -> re-expand -> merge stays exact
# ---------------------------------------------------------------------------


class TestFrozenCascade:
    def test_demote_probe_reexpand_merge_membership_exact(self):
        ka = _keys(20, 2048)
        kb = _keys(21, 1024, lo=2**30, hi=2**31)
        cfg, sa = filters.make("cascade", **FROZEN_SPEC)
        sa = _fill(cfg, sa, ka)
        # demotion actually happened: some frozen level is non-empty
        s = filters.stats(cfg, sa)
        counts = np.asarray(s["level_counts"])
        assert counts[cfg.frozen_below :].sum() > 0
        assert bool(filters.contains(cfg, sa, ka).all())
        # re-expand + merge (host path): union of two frozen cascades
        _, sb = filters.make("cascade", **FROZEN_SPEC)
        sb = _fill(cfg, sb, kb)
        merged = filters.merge(cfg, sa, sb)
        assert bool(filters.contains(cfg, merged, ka).all())
        assert bool(filters.contains(cfg, merged, kb).all())
        assert not bool(filters.stats(cfg, merged)["overflow"])
        # and the merged stream can re-freeze again via grow/resize
        gcfg, gst = filters.grow(cfg, merged)
        assert bool(filters.contains(gcfg, gst, ka).all())
        rcfg, rst = filters.resize(gcfg, gst, levels=4, fanout=4)
        assert bool(filters.contains(rcfg, rst, ka).all())
        assert bool(filters.contains(rcfg, rst, kb).all())

    def test_fp_rate_matches_qf_target(self):
        keys = _keys(22, 2048)
        absent = _keys(23, 16384, lo=2**31, hi=2**32)
        cfg_f, sf = filters.make("cascade", **FROZEN_SPEC)
        cfg_q, sq = filters.make(
            "cascade", **{k: v for k, v in FROZEN_SPEC.items() if k != "frozen_below"}
        )
        sf = _fill(cfg_f, sf, keys)
        sq = _fill(cfg_q, sq, keys)
        rate_f = float(filters.contains(cfg_f, sf, absent).mean())
        rate_q = float(filters.contains(cfg_q, sq, absent).mean())
        # frozen levels are sized to be at least as selective as the QF
        # levels they replace; both targets are ~2^-r and tiny here
        assert rate_f <= rate_q + 3e-3
        assert rate_f < 0.01

    def test_frozen_levels_save_15_percent_bits(self):
        """Acceptance: >= 15% smaller probe-structure bits/key on frozen
        levels than the same levels all-QF, at the same fp-rate target."""
        cfg, _ = filters.make("cascade", **FROZEN_SPEC)
        qf_bytes = sum(
            cfg.level_cfg(i).size_bytes
            for i in range(cfg.levels)
            if cfg.is_frozen(i)
        )
        fz_bytes = sum(
            cfg.level_size_bytes(i) for i in range(cfg.levels) if cfg.is_frozen(i)
        )
        assert fz_bytes <= 0.85 * qf_bytes
        # the cost model's per-level prediction agrees with the geometry
        for i in range(cfg.frozen_below, cfg.levels):
            lvl = cfg.level_cfg(i)
            predicted = cost_model.fuse_bits_per_key(
                lvl.capacity, cfg.fuse_cfg(i).fp_bits
            )
            actual = cfg.fuse_cfg(i).size_bytes * 8 / lvl.capacity
            assert abs(predicted - actual) / actual < 0.02

    def test_scan_ingest_unaffected_for_unfrozen_cascade(self):
        # the device lax.switch path must not see any host branch
        import jax

        cfg, st = filters.make("cascade", ram_q=8, p=26, fanout=2, levels=3)

        def step(s, ks):
            return filters.insert(cfg, s, ks), None

        batches = _keys(24, 16 * 128).reshape(16, 128)
        jaxpr = jax.make_jaxpr(lambda s, bs: jax.lax.scan(step, s, bs)[0])(
            st, batches
        )
        assert [e.primitive.name for e in jaxpr.jaxpr.eqns] == ["scan"]

    def test_pallas_backend_parity(self):
        keys = _keys(25, 2048)
        cfg_r, sr = filters.make("cascade", **FROZEN_SPEC)
        cfg_p, sp = filters.make("cascade", backend="pallas", **FROZEN_SPEC)
        sr = _fill(cfg_r, sr, keys)
        sp = _fill(cfg_p, sp, keys)
        probe_keys = _keys(26, 4096, lo=0, hi=2**32)
        assert bool(
            (
                filters.contains(cfg_r, sr, probe_keys)
                == filters.contains(cfg_p, sp, probe_keys)
            ).all()
        )


# ---------------------------------------------------------------------------
# Satellite 1: cost-model predictions vs measured IOCounters
# ---------------------------------------------------------------------------


class TestCostModelValidation:
    @pytest.mark.parametrize("frozen_below", [None, 0, 1])
    def test_probe_reads_match_prediction(self, frozen_below):
        spec = dict(ram_q=8, p=26, fanout=2, levels=4)
        if frozen_below is not None:
            spec["frozen_below"] = frozen_below
        cfg, st = filters.make("cascade", **spec)
        st = _fill(cfg, st, _keys(30, 2048))
        misses = _keys(31, 1000, lo=2**31, hi=2**32)
        # drop the handful of false positives: they short-circuit early
        # and would under-count vs the all-miss prediction
        fp_mask = np.asarray(filters.contains(cfg, st, misses))
        misses = misses[jnp.asarray(~fp_mask)]
        nq = int(misses.shape[0])

        before = int(st.io.rand_page_reads)
        st2, hit = filters.probe(cfg, st, misses)
        assert not bool(hit.any())
        measured = int(st2.io.rand_page_reads) - before

        counts = np.asarray(filters.stats(cfg, st)["level_counts"])
        nonempty = [int(c) > 0 for c in counts]
        frozen = [cfg.is_frozen(i) for i in range(cfg.levels)]
        predicted = cost_model.cascade_probe_reads(nq, nonempty, frozen)
        assert measured == predicted

    def test_recommend_frozen_below(self):
        # demotion pays at scale: every level of a deep wide cascade
        # clears the default 10% bar at its design point
        assert cost_model.recommend_frozen_below(16, 30, fanout=4, levels=3) == 0
        # no depth clears an impossible bar
        assert (
            cost_model.recommend_frozen_below(16, 30, min_saving=0.99) is None
        )
        # frozen_level_saving agrees with the concrete cascade geometry
        cfg, _ = filters.make("cascade", **FROZEN_SPEC)
        for i in range(cfg.frozen_below, cfg.levels):
            lvl = cfg.level_cfg(i)
            saving = cost_model.frozen_level_saving(
                lvl.q, lvl.r, lvl.slack, cfg.max_load
            )
            actual = 1 - cfg.level_size_bytes(i) / lvl.size_bytes
            assert abs(saving - actual) < 0.02


# ---------------------------------------------------------------------------
# Satellite 2: structured capability errors
# ---------------------------------------------------------------------------


class TestCapabilityErrors:
    def test_insert_on_frozen_family_is_structured(self):
        cfg, st = filters.make("xor_fuse", capacity=256, p=26)
        with pytest.raises(UnsupportedOpError) as ei:
            filters.insert(cfg, st, _keys(40, 16))
        assert ei.value.family == "xor_fuse"
        assert ei.value.op == "insert"
        assert "make(keys=" in ei.value.hint
        # and it still reads as NotImplementedError for legacy callers
        assert isinstance(ei.value, NotImplementedError)

    def test_delete_on_frozen_family_is_structured(self):
        cfg, st = filters.make("xor_fuse", capacity=256, p=26)
        with pytest.raises(UnsupportedOpError) as ei:
            filters.delete(cfg, st, _keys(41, 16))
        assert (ei.value.family, ei.value.op) == ("xor_fuse", "delete")

    def test_delete_on_frozen_cascade_is_config_exact(self):
        cfg, st = filters.make("cascade", **FROZEN_SPEC)
        assert filters.supports("cascade", "delete")  # the family can
        assert not filters.supports(cfg, "delete")  # this config cannot
        with pytest.raises(UnsupportedOpError) as ei:
            filters.delete(cfg, st, _keys(42, 16))
        assert ei.value.op == "delete"

    def test_unknown_op_name_raises_value_error(self):
        cfg, _ = filters.make("qf", q=8, r=8)
        with pytest.raises(ValueError, match="unknown filter op"):
            filters.supports(cfg, "defragment")
        with pytest.raises(ValueError, match="unknown filter op"):
            filters.supports("qf", "inserts")  # typo'd op: no silent False

    def test_auto_scale_surfaces_frozen_insert(self):
        cfg, st = filters.make("xor_fuse", capacity=256, p=26)
        with pytest.raises(UnsupportedOpError):
            filters.auto_scale(cfg, st, _keys(43, 16))

    def test_probe_falls_back_without_binding(self):
        # bloom registers no probe: the façade degrades to contains
        cfg, st = filters.make("bloom", m_bits=1 << 12, k=4)
        st = filters.insert(cfg, st, _keys(44, 64))
        st2, hit = filters.probe(cfg, st, _keys(44, 64))
        assert bool(hit.all())


# ---------------------------------------------------------------------------
# Family-level structural ops
# ---------------------------------------------------------------------------


class TestXorFuseFamily:
    def test_extend_unions_batches(self):
        keys = _keys(50, 1000)
        cfg, st = filters.make("xor_fuse", capacity=1200, p=26)
        st = xor_fuse.extend(cfg, st, keys[:500])
        st = xor_fuse.extend(cfg, st, keys[500:])
        assert bool(filters.contains(cfg, st, keys).all())
        assert int(filters.stats(cfg, st)["n"]) == 1000

    def test_merge_capacity_guard(self):
        cfg, sa = filters.make("xor_fuse", capacity=600, p=26, keys=_keys(51, 400))
        _, sb = filters.make("xor_fuse", capacity=600, p=26, keys=_keys(52, 400))
        with pytest.raises(ValueError, match="exceeds frozen capacity"):
            filters.merge(cfg, sa, sb)

    def test_grow_then_merge_fits(self):
        cfg, sa = filters.make("xor_fuse", capacity=600, p=26, keys=_keys(51, 400))
        _, sb = filters.make("xor_fuse", capacity=600, p=26, keys=_keys(52, 400))
        gcfg, ga = filters.grow(cfg, sa)
        _, gb = filters.grow(cfg, sb)
        merged = filters.merge(gcfg, ga, gb)
        assert bool(filters.contains(gcfg, merged, _keys(51, 400)).all())
        assert bool(filters.contains(gcfg, merged, _keys(52, 400)).all())

    def test_shrink_halves_capacity_membership_exact(self):
        keys = _keys(54, 150)
        cfg, st = filters.make("xor_fuse", capacity=1200, p=26, keys=keys)
        assert bool(filters.needs_shrink(cfg, st))  # 150 < 0.4 * 600
        cfg2, st2 = filters.shrink(cfg, st)
        assert cfg2.capacity == 600
        assert cfg2.fp_bits == cfg.fp_bits  # fp rate unchanged, unlike QF
        assert bool(filters.contains(cfg2, st2, keys).all())
        assert not bool(filters.needs_shrink(cfg2, st2))  # 150 > 0.4 * 300

    def test_probe_charges_three_reads_per_query(self):
        cfg, st = filters.make("xor_fuse", capacity=512, p=26, keys=_keys(53, 512))
        st2, _ = filters.probe(cfg, st, _keys(53, 100))
        assert (
            int(st2.io.rand_page_reads)
            == cost_model.FUSE_PROBE_READS * 100
        )

    def test_snapshot_spec_roundtrip(self):
        cfg, _ = filters.make("xor_fuse", capacity=777, p=26, fp_bits=12)
        cfg2, st2 = filters.make("xor_fuse", **cfg._asdict())
        assert cfg2 == cfg
        assert int(st2.core.n) == 0
