"""Pallas kernel validation: interpret-mode vs pure-jnp oracles.

Sweeps (q, r, n, tile sizes); asserts exact equality (integer data
structures — no tolerance needed) against ref.py and repro.core.
Kernel-exercising tests pin ``mode="interpret"`` explicitly: on CPU the
auto-resolved mode is the XLA lowering, which would silently skip the
kernel bodies.  The xla lowerings get their own parity sweep below.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import fuse_filter as fuse
from repro.core import quotient_filter as qf
from repro.kernels import dispatch, ops, ref
from repro.kernels.qf_build import qf_build_planes
from repro.kernels.qf_probe import qf_probe_tiles


def _mkfilter(q, r, n, seed=0, max_load=1.0, slack=1024):
    cfg = qf.QFConfig(q=q, r=r, slack=slack, max_load=max_load)
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, 2**32, size=n, dtype=np.int64).astype(np.uint32))
    st = qf.insert(cfg, qf.empty(cfg), keys)
    return cfg, st, keys, rng


@pytest.mark.parametrize(
    "q,r,n", [(8, 8, 100), (10, 12, 700), (12, 6, 3000), (14, 16, 12000)]
)
@pytest.mark.parametrize("block_s", [128, 256])
def test_build_kernel_matches_core(q, r, n, block_s):
    cfg, st_ref, keys, _ = _mkfilter(q, r, n)
    fq, fr = qf.fingerprints(cfg, keys)
    fq, fr = qf._pad_sort(fq, fr, jnp.ones(fq.shape, bool))
    st_ker = ops.build_sorted(cfg, fq, fr, n, mode="interpret", block_s=block_s)
    for name, a, b in zip(st_ref._fields, st_ref, st_ker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


def test_build_kernel_matches_ref_oracle():
    """Kernel vs the independent ref.py scatter oracle."""
    cfg, st, keys, _ = _mkfilter(10, 10, 600)
    fq, fr = qf.fingerprints(cfg, keys)
    fq, fr = qf._pad_sort(fq, fr, jnp.ones(fq.shape, bool))
    idx = jnp.arange(fq.shape[0], dtype=jnp.int32)
    pos = idx + jax.lax.cummax(fq - idx)
    con_b = (idx > 0) & (fq == jnp.roll(fq, 1)) & (fq < 2**30)
    shf_b = (pos != fq) & (fq < 2**30)
    spos = jnp.where(fq < 2**30, pos, jnp.int32(2**31 - 1))
    rem_ref, meta_ref, _ = ref.build_ref(
        cfg.total_slots, spos, fq, fr.astype(jnp.int32), con_b, shf_b
    )
    meta_bits = con_b.astype(jnp.int32) | (shf_b.astype(jnp.int32) << 1)
    rem_ker, meta_ker = qf_build_planes(spos, fr, meta_bits, cfg.total_slots)
    np.testing.assert_array_equal(np.asarray(rem_ref), np.asarray(rem_ker))
    np.testing.assert_array_equal(np.asarray(meta_ref), np.asarray(meta_ker))


@pytest.mark.parametrize(
    "q,r,n,load", [(8, 8, 180, 0.7), (10, 10, 900, 0.9), (12, 12, 2000, 0.5)]
)
@pytest.mark.parametrize("tile_t,wblk", [(128, 1024), (256, 512)])
def test_probe_kernel_matches_exact(q, r, n, load, tile_t, wblk):
    cfg, st, keys, rng = _mkfilter(q, r, n, max_load=load)
    probes = jnp.concatenate(
        [
            keys,
            jnp.asarray(
                rng.integers(0, 2**32, size=2 * n, dtype=np.int64).astype(np.uint32)
            ),
        ]
    )
    fq, fr = qf.fingerprints(cfg, probes)
    exact = qf.lookup_exact(cfg, st, fq, fr)
    got = ops.lookup(cfg, st, fq, fr, mode="interpret", tile_t=tile_t, wblk=wblk)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exact))


def test_probe_kernel_matches_ref_oracle():
    """Kernel windowed decode vs the independent ref.py window oracle,
    on queries whose tiles fit (non-overflow path)."""
    cfg, st, keys, rng = _mkfilter(10, 8, 500, max_load=0.6)
    fq, fr = qf.fingerprints(cfg, keys)
    order = jnp.argsort(fq)
    fq_s, fr_s = fq[order], fr[order]
    pad = (-fq_s.shape[0]) % 128
    fq_s = jnp.concatenate([fq_s, jnp.repeat(fq_s[-1:], pad)])
    fr_s = jnp.concatenate([fr_s, jnp.repeat(fr_s[-1:], pad)])
    present, ovf = qf_probe_tiles(
        st.rem.astype(jnp.int32),
        st.occ.astype(jnp.int32),
        st.shf.astype(jnp.int32),
        st.con.astype(jnp.int32),
        fq_s,
        fr_s,
        tile_t=128,
        wblk=1024,
    )
    ref_present, ref_ovf = ref.probe_ref(
        st.rem.astype(jnp.int32),
        st.occ.astype(jnp.int32),
        st.shf.astype(jnp.int32),
        st.con.astype(jnp.int32),
        fq_s,
        fr_s.astype(jnp.int32),
        window=256,
    )
    ok = ~(np.asarray(ovf) > 0) & ~np.asarray(ref_ovf)
    np.testing.assert_array_equal(
        np.asarray(present)[ok] > 0, np.asarray(ref_present)[ok]
    )
    assert ok.mean() > 0.95  # overflow must be rare at this load


@pytest.mark.parametrize("dtype", [jnp.uint32, jnp.int32, jnp.uint16])
def test_key_dtypes(dtype):
    cfg = qf.QFConfig(q=10, r=10, slack=512)
    keys = jnp.arange(500, dtype=dtype)
    st = qf.insert(cfg, qf.empty(cfg), keys)
    assert bool(ops.contains(cfg, st, keys, mode="interpret").all())


def test_high_load_overflow_fallback():
    """At 95% load, clusters exceed any window — the exact fallback
    inside the kernel wrapper must keep answers correct."""
    cfg, st, keys, rng = _mkfilter(9, 12, 486, max_load=0.95)
    probes = jnp.concatenate(
        [
            keys,
            jnp.asarray(
                rng.integers(0, 2**32, size=1000, dtype=np.int64).astype(np.uint32)
            ),
        ]
    )
    fq, fr = qf.fingerprints(cfg, probes)
    exact = qf.lookup_exact(cfg, st, fq, fr)
    got = ops.lookup(cfg, st, fq, fr, mode="interpret", tile_t=128, wblk=256)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exact))


# ---------------------------------------------------------------------------
# Mode dispatch (PR 7): auto-selection, env pin, legacy interpret flag
# ---------------------------------------------------------------------------


class TestDispatch:
    def test_default_mode_is_platform_dependent(self, monkeypatch):
        monkeypatch.delenv("REPRO_KERNEL_MODE", raising=False)
        want = "mosaic" if jax.default_backend() == "tpu" else "xla"
        assert dispatch.default_mode() == want
        assert dispatch.resolve() == want

    def test_env_var_pins_mode(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
        assert dispatch.default_mode() == "interpret"
        assert dispatch.resolve() == "interpret"
        # per-call override still wins over the env pin
        assert dispatch.resolve(mode="xla") == "xla"
        monkeypatch.setenv("REPRO_KERNEL_MODE", "bogus")
        with pytest.raises(ValueError):
            dispatch.default_mode()

    def test_legacy_interpret_flag_maps_to_modes(self):
        assert dispatch.resolve(interpret=True) == "interpret"
        assert dispatch.resolve(interpret=False) == "mosaic"
        with pytest.raises(ValueError):
            dispatch.resolve(mode="fast")

    def test_env_pin_reaches_ops_without_stale_cache(self, monkeypatch):
        """Mode resolution happens outside jit, so flipping the env var
        between calls must actually change the executed lowering."""
        cfg, st, keys, _ = _mkfilter(8, 8, 100)
        fq, fr = qf.fingerprints(cfg, keys)
        monkeypatch.setenv("REPRO_KERNEL_MODE", "xla")
        a = ops.lookup(cfg, st, fq, fr)
        monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
        b = ops.lookup(cfg, st, fq, fr)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# XLA lowering parity: the deployed CPU/GPU path must be bit-identical
# to both the reference ops and the interpreted kernels
# ---------------------------------------------------------------------------


class TestXlaLowering:
    def test_build_matches_reference(self):
        cfg, st_ref, keys, _ = _mkfilter(10, 12, 700)
        fq, fr = qf.fingerprints(cfg, keys)
        fq, fr = qf._pad_sort(fq, fr, jnp.ones(fq.shape, bool))
        st_xla = ops.build_sorted(cfg, fq, fr, 700, mode="xla")
        for name, a, b in zip(st_ref._fields, st_ref, st_xla):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)

    def test_lookup_matches_exact(self):
        cfg, st, keys, rng = _mkfilter(10, 10, 900, max_load=0.9)
        extra = rng.integers(0, 2**32, 2000, np.int64).astype(np.uint32)
        probes = jnp.concatenate([keys, jnp.asarray(extra)])
        fq, fr = qf.fingerprints(cfg, probes)
        np.testing.assert_array_equal(
            np.asarray(ops.lookup(cfg, st, fq, fr, mode="xla")),
            np.asarray(qf.lookup_exact(cfg, st, fq, fr)),
        )

    def test_fuse_lookup_matches_reference(self):
        rng = np.random.default_rng(3)
        keys = jnp.asarray(rng.integers(0, 2**32, 4000, np.int64).astype(np.uint32))
        fc = fuse.make_config(6000, 26, fp_bits=16)
        qc, rc = fuse.canonical_split(26)
        canon = qf.QFConfig(q=qc, r=rc, slack=0)
        fq, fr = qf.fingerprints(canon, keys)
        fq, fr = qf._pad_sort(fq, fr, jnp.ones(fq.shape, bool))
        st = fuse.freeze(fc, fq, fr, keys.shape[0])
        probes = jnp.asarray(rng.integers(0, 2**32, 3000, np.int64).astype(np.uint32))
        pq, pr = qf.fingerprints(canon, probes)
        want = fuse.contains(fc, st, probes)
        for mode in ("xla", "interpret"):
            got = ops.fuse_lookup(fc, st, pq, pr, mode=mode)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(want), err_msg=mode
            )


# ---------------------------------------------------------------------------
# Fused multi-level cascade probe (PR 7 tentpole)
# ---------------------------------------------------------------------------


def _grown_cascade(frozen_below, seed=7, n=3000, backend="pallas"):
    """A cascade ingested far enough that several levels are non-empty."""
    from repro import filters

    cfg, st = filters.make(
        "cascade",
        ram_q=8,
        p=26,
        fanout=2,
        levels=3,
        backend=backend,
        frozen_below=frozen_below,
    )
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, 2**32, n, np.int64).astype(np.uint32))
    for i in range(0, n, 128):
        st = filters.insert(cfg, st, keys[i : i + 128])
    probes = jnp.asarray(rng.integers(0, 2**32, 2048, np.int64).astype(np.uint32))
    return cfg, st, keys, probes


def _per_level_reference(cfg, st, keys):
    """Per-structure hits via the unfused reference path (same guards)."""
    from repro.filters import cascade as cas

    ref_cfg = cfg._replace(backend="reference")
    q0 = jax.lax.cond(
        st.q0.n > 0,
        lambda: qf.contains(cfg.q0_cfg, st.q0, keys, 256),
        lambda: jnp.zeros(keys.shape[0], jnp.bool_),
    )
    return q0, [
        cas._level_contains(ref_cfg, st, i, keys) for i in range(cfg.levels)
    ]


class TestFusedCascadeProbe:
    @pytest.mark.parametrize("frozen_below", [None, 1, 0])
    def test_fused_hits_match_per_level_reference(self, frozen_below):
        cfg, st, keys, probes = _grown_cascade(frozen_below)
        from repro.filters import cascade as cas

        for batch in (probes, keys[:1024]):
            want_q0, want_lvls = _per_level_reference(cfg, st, batch)
            got_q0, got_lvls = cas._fused_level_hits(cfg, st, batch)
            np.testing.assert_array_equal(np.asarray(got_q0), np.asarray(want_q0))
            for i, (g, w) in enumerate(zip(got_lvls, want_lvls)):
                np.testing.assert_array_equal(
                    np.asarray(g), np.asarray(w), err_msg=f"level {i}"
                )

    @pytest.mark.parametrize("frozen_below", [None, 1])
    def test_contains_and_probe_match_reference_backend(self, frozen_below):
        from repro import filters

        cfg, st, keys, probes = _grown_cascade(frozen_below)
        ref_cfg = cfg._replace(backend="reference")
        for batch in (probes, keys):
            np.testing.assert_array_equal(
                np.asarray(filters.contains(cfg, st, batch)),
                np.asarray(filters.contains(ref_cfg, st, batch)),
            )
        st_p, hit_p = filters.probe(cfg, st, probes)
        st_r, hit_r = filters.probe(ref_cfg, st, probes)
        np.testing.assert_array_equal(np.asarray(hit_p), np.asarray(hit_r))
        # the modeled top-down read schedule must not drift either
        assert int(st_p.io.rand_page_reads) == int(st_r.io.rand_page_reads)

    def test_interpret_kernel_matches_xla_lowering(self):
        """The fused Pallas grid (interpret) vs the xla lowering — the
        two deployed lowerings must agree structure-by-structure."""
        cfg, st, keys, probes = _grown_cascade(1, n=2000)
        qf_ix = [i for i in range(cfg.levels) if not cfg.is_frozen(i)]
        fz_ix = [i for i in range(cfg.levels) if cfg.is_frozen(i)]
        args = (
            (cfg.q0_cfg,) + tuple(cfg.level_cfg(i) for i in qf_ix),
            (st.q0,) + tuple(st.levels[i] for i in qf_ix),
            tuple(cfg.fuse_cfg(i) for i in fz_ix),
            tuple(st.levels[i] for i in fz_ix),
        )
        for batch in (probes, keys[:512]):
            a = ops.cascade_lookup(*args, batch, mode="interpret")
            b = ops.cascade_lookup(*args, batch, mode="xla")
            for i, (x, y) in enumerate(zip(a, b)):
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y), err_msg=f"structure {i}"
                )

    def test_window_overflow_fallback_tiles(self):
        """A tiny window forces whole tiles onto the exact-resolve
        fallback; answers must stay bit-exact."""
        cfg, st, keys, probes = _grown_cascade(None, n=2500)
        qf_cfgs = (cfg.q0_cfg,) + tuple(cfg.level_cfg(i) for i in range(cfg.levels))
        qf_states = (st.q0,) + tuple(st.levels)
        for batch in (probes, keys[:1024]):
            want = ops.cascade_lookup(qf_cfgs, qf_states, (), (), batch, mode="xla")
            got = ops.cascade_lookup(
                qf_cfgs, qf_states, (), (), batch, mode="interpret", wblk=128
            )
            for i, (x, y) in enumerate(zip(got, want)):
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y), err_msg=f"structure {i}"
                )

    def test_rejects_mismatched_seeds(self):
        cfg, st, keys, probes = _grown_cascade(None, n=500)
        qf_cfgs = (cfg.q0_cfg, cfg.level_cfg(0)._replace(seed=99))
        with pytest.raises(ValueError):
            ops.cascade_lookup(qf_cfgs, (st.q0, st.levels[0]), (), (), probes)


# ---------------------------------------------------------------------------
# Blocked-Bloom bin kernels (PR 7 tentpole)
# ---------------------------------------------------------------------------


class TestBloomBinKernels:
    def _idx(self, seed, n, ncells, k=4, nblocks=32):
        """(n, k) indices with blocked locality over ``nblocks`` bins."""
        rng = np.random.default_rng(seed)
        blk = rng.integers(0, nblocks, n)
        span = ncells // nblocks
        inner = rng.integers(0, span, (n, k))
        return jnp.asarray((blk[:, None] * span + inner).astype(np.int32))

    @pytest.mark.parametrize("block_s", [256, 512])
    def test_counts_match_scatter(self, block_s):
        ncells = 1 << 13
        idx = self._idx(0, 3000, ncells).reshape(-1)
        want = ops.bloom_counts(idx, ncells, mode="xla")
        got = ops.bloom_counts(idx, ncells, mode="interpret", block_s=block_s)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_counts_dense_bins_fall_back_exactly(self):
        """Hammer two bins so their tiles outrun the item window — the
        per-tile scatter recount must splice in bit-exactly."""
        ncells = 1 << 12
        rng = np.random.default_rng(1)
        hot = rng.integers(0, 256, 6000).astype(np.int32)  # ~23 items/cell
        cold = self._idx(2, 1000, ncells).reshape(-1)
        idx = jnp.concatenate([jnp.asarray(hot), cold])
        want = ops.bloom_counts(idx, ncells, mode="xla")
        got = ops.bloom_counts(idx, ncells, mode="interpret", block_s=128)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_counts_drop_masked_sentinels(self):
        ncells = 1 << 10
        idx = jnp.concatenate(
            [
                self._idx(3, 500, ncells, nblocks=8).reshape(-1),
                jnp.full((64,), jnp.int32(2**31 - 1)),  # masked keys
            ]
        )
        got = ops.bloom_counts(idx, ncells, mode="interpret")
        want = ops.bloom_counts(idx, ncells, mode="xla")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert int(jnp.sum(got)) == 500 * 4  # sentinels landed nowhere

    @pytest.mark.parametrize("k", [2, 4, 7])
    def test_probe_matches_gather(self, k):
        ncells = 1 << 13
        ins = self._idx(4, 2000, ncells, k=k)
        cells = (
            ops.bloom_counts(ins.reshape(-1), ncells, mode="xla") > 0
        ).astype(jnp.uint8)
        queries = jnp.concatenate([ins[:700], self._idx(5, 1300, ncells, k=k)])
        want = ops.bloom_probe(cells, queries, mode="xla")
        got = ops.bloom_probe(cells, queries, mode="interpret")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_probe_overflow_window_fallback(self):
        """wblk smaller than a bin span: every tile overflows, the exact
        fallback must carry the whole batch."""
        ncells = 1 << 12
        ins = self._idx(6, 1500, ncells, nblocks=4)  # 1024-cell bins
        cells = (
            ops.bloom_counts(ins.reshape(-1), ncells, mode="xla") > 0
        ).astype(jnp.uint8)
        queries = self._idx(7, 1000, ncells, nblocks=4)
        want = ops.bloom_probe(cells, queries, mode="xla")
        got = ops.bloom_probe(cells, queries, mode="interpret", wblk=256)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("counting", [False, True])
    def test_blocked_bloom_filter_end_to_end(self, counting, monkeypatch):
        from repro import filters

        # pin the interpreter: with the platform default (xla on CPU)
        # insert/delete route to the reference scatter directly, which
        # would make this parity check compare identical code
        monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
        rng = np.random.default_rng(8)
        keys = jnp.asarray(rng.integers(0, 2**32, 4000, np.int64).astype(np.uint32))
        probes = jnp.asarray(rng.integers(0, 2**32, 2000, np.int64).astype(np.uint32))
        spec = dict(m_bits=1 << 16, k=4, block_bits=512, counting=counting)
        c_r, s_r = filters.make("blocked_bloom", **spec)
        c_p, s_p = filters.make("blocked_bloom", **spec, backend="pallas")
        s_r = filters.insert(c_r, s_r, keys)
        s_p = filters.insert(c_p, s_p, keys)
        np.testing.assert_array_equal(np.asarray(s_r.cells), np.asarray(s_p.cells))
        for batch in (probes, keys[:1000]):
            np.testing.assert_array_equal(
                np.asarray(filters.contains(c_r, s_r, batch)),
                np.asarray(filters.contains(c_p, s_p, batch)),
            )
        if counting:
            s_r = filters.delete(c_r, s_r, keys[:500])
            s_p = filters.delete(c_p, s_p, keys[:500])
            np.testing.assert_array_equal(
                np.asarray(s_r.cells), np.asarray(s_p.cells)
            )


# ---------------------------------------------------------------------------
# Kernel-vs-oracle parity (the spec checker's declared bindings)
# ---------------------------------------------------------------------------


class TestOracleParity:
    """Direct wrapper-vs-ref.py parity, one test per spec-check binding.

    ``repro.analysis.spec_check`` asserts every kernel wrapper has a
    bound pure-jnp oracle and a parity test; these are those tests for
    the kernels whose existing coverage went through ``ops`` only.
    """

    def test_bloom_probe_tiles_matches_bloom_probe_ref(self):
        from repro.kernels.bloom_block import bloom_probe_tiles

        ncells, k = 1 << 12, 4
        rng = np.random.default_rng(11)
        cells = jnp.asarray(rng.integers(0, 2, ncells).astype(np.int32))
        blk = rng.integers(0, 16, 512)
        span = ncells // 16
        idx = np.sort(
            (blk[:, None] * span + rng.integers(0, span, (512, k))).astype(np.int32),
            axis=1,
        )
        idx = jnp.asarray(idx[np.argsort(idx.min(axis=1), kind="stable")])
        hit, ovf = bloom_probe_tiles(cells, idx, tile_t=128, wblk=1024)
        want = ref.bloom_probe_ref(cells, idx)
        ok = np.asarray(ovf) == 0
        assert ok.any()
        np.testing.assert_array_equal(
            np.asarray(hit, bool)[ok], np.asarray(want)[ok]
        )

    def test_bloom_count_tiles_matches_bloom_count_ref(self):
        from repro.kernels.bloom_block import bloom_count_tiles

        ncells = 1 << 10
        rng = np.random.default_rng(12)
        idx = jnp.asarray(np.sort(rng.integers(0, ncells, 800)).astype(np.int32))
        counts, fits = bloom_count_tiles(idx, ncells, block_s=256)
        want = ref.bloom_count_ref(idx, ncells)
        got = np.asarray(counts)[:ncells]
        mask = np.repeat(np.asarray(fits), 256)[:ncells]
        assert mask.any()
        np.testing.assert_array_equal(got[mask], np.asarray(want)[mask])

    def test_cascade_probe_tiles_matches_cascade_probe_ref(self):
        from repro.kernels.cascade_probe import cascade_probe_tiles

        # coherent single-slot runs: items at pos == fq, no shifting
        def mkplanes(total, occupied_fq, fr_of):
            pos = jnp.asarray(occupied_fq, jnp.int32)
            fr = fr_of(pos)
            rem, meta, occ = ref.build_ref(
                total, pos, pos, fr,
                jnp.zeros_like(pos), jnp.zeros_like(pos),
            )
            con = meta & 1
            shf = meta >> 1
            return rem, occ, shf, con

        planes = [
            mkplanes(256, np.arange(0, 256, 2), lambda p: p + 1),
            mkplanes(512, np.arange(0, 512, 3), lambda p: p * 2 + 1),
        ]
        B = 128
        fq0 = jnp.arange(B, dtype=jnp.int32)
        fq_levels = [fq0, fq0 * 2]
        fr_levels = [fq0 + 1, (fq0 * 2) * 2 + 1]  # all stored fr match
        hit, ovf = cascade_probe_tiles(
            planes, fq_levels, fr_levels, tile_t=32, wblk=256
        )
        rhit, rovf = ref.cascade_probe_ref(planes, fq_levels, fr_levels, window=8)
        ok = (np.asarray(ovf) == 0) & (np.asarray(rovf) == 0)
        assert ok.any()
        np.testing.assert_array_equal(np.asarray(hit)[ok], np.asarray(rhit)[ok])

    def test_fuse_probe_tiles_matches_fuse_probe_ref(self):
        from repro.kernels.fuse_probe import fuse_probe_tiles

        total = 1 << 11
        rng = np.random.default_rng(13)
        table = jnp.asarray(rng.integers(0, 2**32, total, np.int64).astype(np.uint32))
        p0 = np.sort(rng.integers(0, total - 3, 256)).astype(np.int32)
        p1, p2 = p0 + 1, p0 + 2
        fp_hit = np.asarray(table)[p0] ^ np.asarray(table)[p1] ^ np.asarray(table)[p2]
        fp = fp_hit.copy()
        fp[::2] ^= np.uint32(0xDEAD)  # force misses on even rows
        args = tuple(map(jnp.asarray, (p0, p1, p2, fp)))
        hit, ovf = fuse_probe_tiles(
            table.view(jnp.int32), *args, tile_t=64, wblk=512
        )
        want = ref.fuse_probe_ref(table, *args)
        ok = np.asarray(ovf) == 0
        assert ok.any()
        np.testing.assert_array_equal(np.asarray(hit, bool)[ok], np.asarray(want)[ok])
