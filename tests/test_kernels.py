"""Pallas kernel validation: interpret-mode vs pure-jnp oracles.

Sweeps (q, r, n, tile sizes); asserts exact equality (integer data
structures — no tolerance needed) against ref.py and repro.core.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import quotient_filter as qf
from repro.kernels import ops, ref
from repro.kernels.qf_build import qf_build_planes
from repro.kernels.qf_probe import qf_probe_tiles


def _mkfilter(q, r, n, seed=0, max_load=1.0, slack=1024):
    cfg = qf.QFConfig(q=q, r=r, slack=slack, max_load=max_load)
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, 2**32, size=n, dtype=np.int64).astype(np.uint32))
    st = qf.insert(cfg, qf.empty(cfg), keys)
    return cfg, st, keys, rng


@pytest.mark.parametrize(
    "q,r,n", [(8, 8, 100), (10, 12, 700), (12, 6, 3000), (14, 16, 12000)]
)
@pytest.mark.parametrize("block_s", [128, 256])
def test_build_kernel_matches_core(q, r, n, block_s):
    cfg, st_ref, keys, _ = _mkfilter(q, r, n)
    fq, fr = qf.fingerprints(cfg, keys)
    fq, fr = qf._pad_sort(fq, fr, jnp.ones(fq.shape, bool))
    st_ker = ops.build_sorted(cfg, fq, fr, n, block_s=block_s)
    for name, a, b in zip(st_ref._fields, st_ref, st_ker):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


def test_build_kernel_matches_ref_oracle():
    """Kernel vs the independent ref.py scatter oracle."""
    cfg, st, keys, _ = _mkfilter(10, 10, 600)
    fq, fr = qf.fingerprints(cfg, keys)
    fq, fr = qf._pad_sort(fq, fr, jnp.ones(fq.shape, bool))
    idx = jnp.arange(fq.shape[0], dtype=jnp.int32)
    pos = idx + jax.lax.cummax(fq - idx)
    con_b = (idx > 0) & (fq == jnp.roll(fq, 1)) & (fq < 2**30)
    shf_b = (pos != fq) & (fq < 2**30)
    spos = jnp.where(fq < 2**30, pos, jnp.int32(2**31 - 1))
    rem_ref, meta_ref, _ = ref.build_ref(
        cfg.total_slots, spos, fq, fr.astype(jnp.int32), con_b, shf_b
    )
    meta_bits = con_b.astype(jnp.int32) | (shf_b.astype(jnp.int32) << 1)
    rem_ker, meta_ker = qf_build_planes(spos, fr, meta_bits, cfg.total_slots)
    np.testing.assert_array_equal(np.asarray(rem_ref), np.asarray(rem_ker))
    np.testing.assert_array_equal(np.asarray(meta_ref), np.asarray(meta_ker))


@pytest.mark.parametrize(
    "q,r,n,load", [(8, 8, 180, 0.7), (10, 10, 900, 0.9), (12, 12, 2000, 0.5)]
)
@pytest.mark.parametrize("tile_t,wblk", [(128, 1024), (256, 512)])
def test_probe_kernel_matches_exact(q, r, n, load, tile_t, wblk):
    cfg, st, keys, rng = _mkfilter(q, r, n, max_load=load)
    probes = jnp.concatenate(
        [
            keys,
            jnp.asarray(
                rng.integers(0, 2**32, size=2 * n, dtype=np.int64).astype(np.uint32)
            ),
        ]
    )
    fq, fr = qf.fingerprints(cfg, probes)
    exact = qf.lookup_exact(cfg, st, fq, fr)
    got = ops.lookup(cfg, st, fq, fr, tile_t=tile_t, wblk=wblk)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exact))


def test_probe_kernel_matches_ref_oracle():
    """Kernel windowed decode vs the independent ref.py window oracle,
    on queries whose tiles fit (non-overflow path)."""
    cfg, st, keys, rng = _mkfilter(10, 8, 500, max_load=0.6)
    fq, fr = qf.fingerprints(cfg, keys)
    order = jnp.argsort(fq)
    fq_s, fr_s = fq[order], fr[order]
    pad = (-fq_s.shape[0]) % 128
    fq_s = jnp.concatenate([fq_s, jnp.repeat(fq_s[-1:], pad)])
    fr_s = jnp.concatenate([fr_s, jnp.repeat(fr_s[-1:], pad)])
    present, ovf = qf_probe_tiles(
        st.rem.astype(jnp.int32),
        st.occ.astype(jnp.int32),
        st.shf.astype(jnp.int32),
        st.con.astype(jnp.int32),
        fq_s,
        fr_s,
        tile_t=128,
        wblk=1024,
    )
    ref_present, ref_ovf = ref.probe_ref(
        st.rem.astype(jnp.int32),
        st.occ.astype(jnp.int32),
        st.shf.astype(jnp.int32),
        st.con.astype(jnp.int32),
        fq_s,
        fr_s.astype(jnp.int32),
        window=256,
    )
    ok = ~(np.asarray(ovf) > 0) & ~np.asarray(ref_ovf)
    np.testing.assert_array_equal(
        np.asarray(present)[ok] > 0, np.asarray(ref_present)[ok]
    )
    assert ok.mean() > 0.95  # overflow must be rare at this load


@pytest.mark.parametrize("dtype", [jnp.uint32, jnp.int32, jnp.uint16])
def test_key_dtypes(dtype):
    cfg = qf.QFConfig(q=10, r=10, slack=512)
    keys = jnp.arange(500, dtype=dtype)
    st = qf.insert(cfg, qf.empty(cfg), keys)
    assert bool(ops.contains(cfg, st, keys).all())


def test_high_load_overflow_fallback():
    """At 95% load, clusters exceed any window — the exact fallback
    inside the kernel wrapper must keep answers correct."""
    cfg, st, keys, rng = _mkfilter(9, 12, 486, max_load=0.95)
    probes = jnp.concatenate(
        [
            keys,
            jnp.asarray(
                rng.integers(0, 2**32, size=1000, dtype=np.int64).astype(np.uint32)
            ),
        ]
    )
    fq, fr = qf.fingerprints(cfg, probes)
    exact = qf.lookup_exact(cfg, st, fq, fr)
    got = ops.lookup(cfg, st, fq, fr, tile_t=128, wblk=256)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exact))
