"""Per-architecture smoke tests: reduced configs, one forward/train step
on CPU, shape + finiteness checks, decode == full-forward equivalence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, make_smoke
from repro.models import model


def _batch(cfg, rng, B=2, S=24, with_targets=True):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    }
    if with_targets:
        batch["targets"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
        )
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(name):
    cfg = make_smoke(get_config(name))
    rng = np.random.default_rng(0)
    params = model.init(cfg, 0)
    batch = _batch(cfg, rng)
    logits, _, aux = model.forward(params, cfg, batch, remat=False)
    assert logits.shape == (2, 24, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_one_train_step(name):
    """One SGD step must produce finite loss + grads and change params."""
    cfg = make_smoke(get_config(name))
    rng = np.random.default_rng(1)
    params = model.init(cfg, 0)
    batch = _batch(cfg, rng)

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, cfg, batch, remat=True), has_aux=True
    )(params)
    assert bool(jnp.isfinite(loss)), name
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    new = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
    loss2, _ = model.loss_fn(new, cfg, batch, remat=False)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_full_forward(name):
    cfg = make_smoke(get_config(name))
    if cfg.is_moe:
        cfg = cfg.replace(capacity_factor=64.0)  # no token drops -> exact
    rng = np.random.default_rng(2)
    params = model.init(cfg, 0)
    B, S = 2, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)
    batch = _batch(cfg, rng, B=B, S=S, with_targets=False)
    batch["tokens"] = toks[:, :S]
    full = dict(batch, tokens=toks)
    logits_full, _, _ = model.forward(params, cfg, full, mode="train", remat=False)
    last, cache = model.prefill(params, cfg, batch, remat=False)
    scale = float(jnp.max(jnp.abs(logits_full)))
    assert float(jnp.max(jnp.abs(last - logits_full[:, S - 1]))) / scale < 2e-3
    step, cache = model.decode_step(params, cfg, cache, toks[:, S : S + 1])
    assert float(jnp.max(jnp.abs(step - logits_full[:, S]))) / scale < 2e-3
    assert int(cache["pos"]) == S + 1


@pytest.mark.parametrize("name", ARCHS)
def test_multi_step_decode_stable(name):
    cfg = make_smoke(get_config(name))
    rng = np.random.default_rng(3)
    params = model.init(cfg, 0)
    batch = _batch(cfg, rng, with_targets=False)
    _, cache = model.prefill(params, cfg, batch, remat=False)
    tok = batch["tokens"][:, -1:]
    for _ in range(4):
        logits, cache = model.decode_step(params, cfg, cache, tok)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)


def test_param_count_vs_schema():
    """Analytic param count must be within 1.5% of the real tree (big cfgs)."""
    for name in ARCHS:
        cfg = get_config(name)
        sch = model.schema(cfg)
        import repro.models.schema as S

        total = sum(
            int(np.prod(p.shape))
            for p in jax.tree.leaves(sch, is_leaf=S.is_param)
        )
        analytic = cfg.param_count()
        rel = abs(total - analytic) / total
        assert rel < 0.015, f"{name}: schema {total:,} vs analytic {analytic:,}"


def test_full_config_headline_params():
    """Sanity: full configs land near their nameplate sizes."""
    import repro.models.schema as S

    expect = {
        "grok-1-314b": (290e9, 340e9),
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "qwen3-8b": (7e9, 9.5e9),
        "gemma-7b": (7.5e9, 9.5e9),
        "deepseek-7b": (6e9, 8e9),
        "starcoder2-15b": (14e9, 17e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "recurrentgemma-9b": (8e9, 11e9),
        "qwen2-vl-7b": (6.5e9, 8.5e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
    }
    for name, (lo, hi) in expect.items():
        cfg = get_config(name)
        sch = model.schema(cfg)
        total = sum(
            int(np.prod(p.shape))
            for p in jax.tree.leaves(sch, is_leaf=S.is_param)
        )
        assert lo <= total <= hi, (
            f"{name}: {total/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
        )
