"""Data pipeline, checkpointing, fault-tolerance, optimizer tests."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.pipeline import DedupPipeline, PipelineConfig
from repro.train import optimizer as optim
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import (
    ClusterMonitor,
    FTConfig,
    HostState,
    TrainSupervisor,
    plan_rescale,
)


class TestPipeline:
    def test_dedup_drops_duplicates(self):
        pipe = DedupPipeline(
            PipelineConfig(seq_len=128, batch_size=2, duplicate_fraction=0.5, seed=1)
        )
        batches = list(pipe.batches(3, docs_per_step=128))
        assert len(batches) == 3
        assert pipe.state.docs_dropped > 0
        # with a 0.5 dup fraction, drop rate should be near 50%
        rate = pipe.state.docs_dropped / pipe.state.docs_seen
        assert 0.3 < rate < 0.7
        for b in batches:
            assert b["tokens"].shape == (2, 128)
            # targets are next-token shifted
            flat_t = np.asarray(b["tokens"]).ravel()
            flat_y = np.asarray(b["targets"]).ravel()
            np.testing.assert_array_equal(flat_t[1:], flat_y[:-1])

    def test_zero_duplicates_passthrough(self):
        pipe = DedupPipeline(
            PipelineConfig(seq_len=64, batch_size=2, duplicate_fraction=0.0, seed=2)
        )
        list(pipe.batches(2, docs_per_step=64))
        # only false positives (~n * 2^-p) may drop; at this scale: none
        assert pipe.state.docs_dropped <= 1

    def test_snapshot_restore_preserves_filter(self):
        cfgp = PipelineConfig(seq_len=64, batch_size=2, duplicate_fraction=0.3, seed=3)
        pipe = DedupPipeline(cfgp)
        list(pipe.batches(2, docs_per_step=128))
        snap = pipe.snapshot()
        seen_before = pipe.state.docs_seen

        pipe2 = DedupPipeline(cfgp)
        pipe2.restore(snap)
        assert pipe2.state.docs_seen == seen_before
        # re-offering the same originals must now be dropped as dups
        ids = np.asarray(pipe.corpus._originals[:50], np.uint32)
        keep = pipe2._dedup(ids)
        assert not keep.any()


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last_k=2)
        state = {"a": jnp.arange(10, dtype=jnp.float32), "b": {"c": jnp.ones((3, 3))}}
        mgr.save(5, state)
        assert mgr.latest_step() == 5
        got = mgr.restore(5, jax.eval_shape(lambda: state))
        np.testing.assert_array_equal(
            np.asarray(got["a"]), np.arange(10, dtype=np.float32)
        )

    def test_gc_keeps_last_k(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last_k=2)
        state = {"x": jnp.zeros(4)}
        for s in (1, 2, 3, 4):
            mgr.save(s, state)
        steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
        assert steps == ["step_00000003", "step_00000004"]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        state = {"x": jnp.arange(1000)}
        mgr.save(1, state, background=True)
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_corruption_detected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        state = {"x": jnp.arange(16, dtype=jnp.int32)}
        mgr.save(1, state)
        # flip bytes in the shard
        import numpy as np_

        p = tmp_path / "step_00000001" / "shard_0.npz"
        data = dict(np_.load(p))
        data["leaf_0"] = data["leaf_0"] + 1
        np_.savez(p, **data)
        with pytest.raises(IOError):
            mgr.restore(1, jax.eval_shape(lambda: state))

    def test_structure_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.zeros(4)})
        with pytest.raises(ValueError):
            mgr.restore(
                1, jax.eval_shape(lambda: {"x": jnp.zeros(4), "y": jnp.zeros(2)})
            )


class TestFaultTolerance:
    def _fake_clock(self):
        t = [0.0]

        def clock():
            return t[0]

        return t, clock

    def test_heartbeat_death_and_rescale(self):
        t, clock = self._fake_clock()
        cfg = FTConfig(heartbeat_timeout_s=30)
        mon = ClusterMonitor([f"h{i}" for i in range(8)], cfg, clock=clock)
        t[0] = 10.0
        for h in ("h0", "h1", "h2", "h3", "h4", "h5"):
            mon.heartbeat(h)
        t[0] = 35.0  # h6, h7 (last beat t=0) missed the 30s timeout
        dead = mon.sweep()
        assert set(dead) == {"h6", "h7"}
        plan = plan_rescale(mon, current_dp=4, hosts_per_replica=2, cfg=cfg)
        assert plan.action == "restore_rescale"
        assert plan.data_parallel == 3  # 6 healthy / 2 per replica

    def test_halt_below_min(self):
        t, clock = self._fake_clock()
        cfg = FTConfig(min_data_parallel=3)
        mon = ClusterMonitor(["h0", "h1", "h2", "h3"], cfg, clock=clock)
        t[0] = 100.0
        mon.sweep()  # everyone dead
        plan = plan_rescale(mon, current_dp=4, hosts_per_replica=1, cfg=cfg)
        assert plan.action == "halt"

    def test_straggler_suspects(self):
        t, clock = self._fake_clock()
        cfg = FTConfig(step_deadline_s=10, suspect_strikes=2)
        mon = ClusterMonitor(["h0", "h1"], cfg, clock=clock)
        mon.step_completed(50.0, slow_hosts=["h1"])
        assert mon.state["h1"] is HostState.HEALTHY
        mon.step_completed(50.0, slow_hosts=["h1"])
        assert mon.state["h1"] is HostState.SUSPECT
        mon.heartbeat("h1")
        assert mon.state["h1"] is HostState.HEALTHY

    def test_supervisor_restores_on_failure(self):
        t, clock = self._fake_clock()
        cfg = FTConfig()
        mon = ClusterMonitor(["h0", "h1", "h2", "h3"], cfg, clock=clock)
        restored = []
        sup = TrainSupervisor(
            mon, cfg, hosts_per_replica=1, current_dp=4,
            on_restore=lambda dp: restored.append(dp),
        )
        out = sup.run_step(lambda: {"loss": 1.0})
        assert out is not None
        t[0] = 100.0
        mon.heartbeat("h0"); mon.heartbeat("h1"); mon.heartbeat("h2")
        out = sup.run_step(lambda: {"loss": 1.0})
        assert out is None and restored == [3] and sup.restarts == 1


class TestOptimizer:
    def test_adamw_reduces_loss(self):
        ocfg = optim.OptConfig(
            lr=0.1, warmup_steps=1, total_steps=100, weight_decay=0.0
        )
        params = {"w": jnp.asarray([3.0, -2.0])}
        opt = optim.init(params, ocfg)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        for _ in range(50):
            g = jax.grad(loss)(params)
            params, opt, m = optim.apply(params, g, opt, ocfg)
        assert float(loss(params)) < 0.1

    def test_bf16_moments(self):
        ocfg = optim.OptConfig(opt_dtype="bfloat16")
        params = {"w": jnp.ones((4, 4))}
        opt = optim.init(params, ocfg)
        assert opt.mu["w"].dtype == jnp.bfloat16
        g = {"w": jnp.full((4, 4), 0.1)}
        p2, opt2, _ = optim.apply(params, g, opt, ocfg)
        assert jnp.all(jnp.isfinite(p2["w"]))

    def test_grad_compression_error_feedback(self):
        """EF-int8 compression: biased per-step but the residual carries
        the error so the cumulative update converges to the true sum."""
        ocfg = optim.OptConfig(compress_grads=True, lr=0.01, weight_decay=0.0,
                               warmup_steps=1)
        g = jnp.asarray([1e-4, 0.5, -0.3, 2.0])
        err = jnp.zeros(4, jnp.bfloat16)
        total = jnp.zeros(4)
        for _ in range(64):
            deq, err = optim.compress_int8(g, err)
            total = total + deq
        np.testing.assert_allclose(
            np.asarray(total / 64), np.asarray(g), rtol=0.05, atol=1e-4
        )

    def test_schedule_warmup_and_decay(self):
        ocfg = optim.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
        assert float(optim.schedule(ocfg, 5)) == pytest.approx(0.5)
        assert float(optim.schedule(ocfg, 10)) == pytest.approx(1.0)
        assert float(optim.schedule(ocfg, 100)) == pytest.approx(0.1, abs=0.01)
