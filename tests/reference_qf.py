"""Paper-faithful item-at-a-time quotient filter (pure Python).

Implements §3 of the paper directly — the MAY-CONTAIN walk of Fig. 3
and the shifting insert — on the non-wrapping layout used by the JAX
port (runs kept sorted by remainder, which the paper's in-order
traversal property implies).  Used as an *independent structural
oracle*: the bulk-parallel build must reproduce these slot planes
bit-for-bit.
"""

from __future__ import annotations


class PaperQF:
    def __init__(self, q: int, r: int, slack: int = 1024):
        self.q, self.r = q, r
        self.m = 1 << q
        self.total = self.m + slack
        t = self.total
        self.occ = [False] * t
        self.shf = [False] * t
        self.con = [False] * t
        self.rem = [0] * t
        self.n = 0

    # -- decoding helpers ---------------------------------------------------

    def _free(self, i: int) -> bool:
        """Slot i holds no remainder (occupied implies in-cluster)."""
        return not (self.occ[i] or self.shf[i])

    def _run_start(self, fq: int) -> int:
        """The walk of Fig. 3: anchor at the cluster start, skip the runs
        of earlier occupied buckets."""
        b = fq
        while self.shf[b]:
            b -= 1
        s = b
        while b != fq:
            # skip all elements in the current run
            s += 1
            while self.con[s]:
                s += 1
            # find the next occupied bucket
            b += 1
            while not self.occ[b]:
                b += 1
        return s

    def contains(self, fq: int, fr: int) -> bool:
        if not self.occ[fq]:
            return False
        s = self._run_start(fq)
        while True:
            if self.rem[s] == fr:
                return True
            s += 1
            if not self.con[s]:
                return False

    # -- the paper's shifting insert -----------------------------------------

    def insert(self, fq: int, fr: int) -> None:
        self.n += 1
        if self._free(fq):
            self.occ[fq] = True
            self.rem[fq] = fr
            return
        was_occ = self.occ[fq]
        self.occ[fq] = True
        s = self._run_start(fq)
        run_head = s
        if was_occ:
            # advance to the sorted position within the existing run
            while self.rem[s] < fr:
                nxt = s + 1
                if not self.con[nxt]:
                    s = nxt  # one past the run's end
                    break
                s = nxt
        at_head = s == run_head
        displaced_head = was_occ and at_head
        # shift everything right from s to the first free slot
        e = s
        while not self._free(e):
            e += 1
        for i in range(e, s, -1):
            self.rem[i] = self.rem[i - 1]
            self.con[i] = self.con[i - 1]
            self.shf[i] = True
        self.rem[s] = fr
        self.con[s] = was_occ and not at_head
        self.shf[s] = s != fq
        if displaced_head:
            self.con[s + 1] = True

    def planes(self):
        return list(self.rem), list(self.occ), list(self.shf), list(self.con)
