"""Dynamic-resizing tests: the resize/needs_resize/grow protocol and the
``auto_grow`` ingest driver, across every registered filter family.

The paper's abstract claims the QF "can be dynamically resized"; these
tests pin the end-to-end version of that claim:

* growing preserves the stored fingerprint multiset exactly (and a
  grow-then-shrink round-trip is the identity on the multiset);
* no false negatives across any growth step, for any family;
* ``auto_grow`` ingest of 8x a filter's initial capacity completes with
  no overflow, and — for the QF family, whose p-bit fingerprints are
  split-invariant — answers *identically* to a filter built statically
  at the final size;
* ``cascade.merge`` of two cascades whose same-index levels are each
  more than half full no longer trips level overflow (regression);
* ``build_sorted``'s sentinel arithmetic does not depend on the amount
  of padding (regression for the int32 wraparound).
"""

import numpy as np
import jax.numpy as jnp
import pytest

try:  # property tests degrade to skips without hypothesis (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*a, **k):  # inert decorator stand-ins so the module imports
        return lambda f: f

    settings = given

    class _Anything:
        def __getattr__(self, name):
            return self

        def __call__(self, *a, **k):
            return self

    st = _Anything()

from repro import filters
from repro.core import quotient_filter as qf

# name -> (registry name, spec, chunk): specs sized so 8x growth fits the
# fingerprint budget; chunks stay below each structure's slack
GROW_CASES = {
    "qf": ("qf", dict(q=8, r=16), 128),
    "qf_pallas": ("qf", dict(q=8, r=16, backend="pallas"), 128),
    "bloom": ("bloom", dict(m_bits=1 << 12, k=6, counting=True), 128),
    "blocked_bloom": (
        "blocked_bloom",
        dict(m_bits=1 << 14, k=6, block_bits=1 << 10),
        128,
    ),
    "buffered_qf": ("buffered_qf", dict(ram_q=7, disk_q=10, p=26), 64),
    "cascade": ("cascade", dict(ram_q=7, p=30, fanout=4, levels=1), 64),
    "sharded_qf": ("sharded_qf", dict(q=8, r=16, n_shards=1), 64),
    "steady_qf": ("steady_qf", dict(q=9, r=16), 64),
}


def _keys(seed, n, lo=0, hi=2**31):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi, size=n, dtype=np.int64).astype(np.uint32))


def _initial_capacity(name, cfg) -> int:
    if name == "qf":
        return cfg.core.capacity
    if name == "steady_qf":
        return cfg.table.capacity
    if name == "buffered_qf":
        return cfg.disk.capacity
    if name == "cascade":
        return cfg.level_cfg(cfg.levels - 1).capacity
    if name == "sharded_qf":
        return cfg.core.local_cfg.capacity * cfg.n_shards
    from repro.filters import bloom_filter as bf

    return bf._capacity(cfg)


@pytest.fixture(params=sorted(GROW_CASES), name="case")
def _case(request):
    return request.param


class TestProtocol:
    def test_every_family_answers_resize_through_facade(self):
        """Acceptance: resize/needs_resize/grow for every registered name."""
        for name in filters.names():
            assert filters.supports(name, "resize"), name
            assert filters.supports(name, "grow"), name
            assert filters.supports(name, "needs_resize"), name

    def test_needs_resize_is_device_scalar_and_jittable(self, case):
        import jax

        name, spec, _ = GROW_CASES[case]
        cfg, stt = filters.make(name, **spec)
        flag = jax.jit(lambda s: filters.needs_resize(cfg, s))(stt)
        assert flag.shape == () and flag.dtype == jnp.bool_
        assert not bool(flag)

    def test_grow_doubles_and_clears_predicate(self, case):
        name, spec, chunk = GROW_CASES[case]
        cfg, stt = filters.make(name, **spec)
        keys = _keys(1, _initial_capacity(name, cfg))
        for i in range(0, keys.shape[0], chunk):
            stt = filters.insert(cfg, stt, keys[i : i + chunk])
        assert bool(filters.needs_resize(cfg, stt))
        new_cfg, new_st = filters.grow(cfg, stt)
        assert new_cfg != cfg
        assert not bool(filters.needs_resize(new_cfg, new_st))
        assert bool(filters.contains(new_cfg, new_st, keys).all())


class TestAutoGrow:
    def test_ingest_8x_initial_capacity(self, case):
        """Acceptance: 8x growth, zero false negatives, no overflow."""
        name, spec, chunk = GROW_CASES[case]
        cfg, stt = filters.make(name, **spec)
        cap0 = _initial_capacity(name, cfg)
        n = 8 * cap0
        n += (-n) % chunk  # sharded insert needs whole batches
        keys = _keys(2, n)
        for i in range(0, n, chunk):
            cfg, stt = filters.auto_grow(cfg, stt, keys[i : i + chunk])
        s = filters.stats(cfg, stt)
        assert int(s["n"]) == n
        if "overflow" in s:
            assert not bool(s["overflow"])
        assert bool(filters.contains(cfg, stt, keys).all())

    def test_qf_auto_grow_matches_static_filter(self):
        """QF fingerprints are (q, r)-split-invariant, so a grown filter
        answers exactly like one built statically at the final size."""
        cfg, stt = filters.make("qf", q=8, r=16)
        keys = _keys(3, 8 * cfg.core.capacity)
        for i in range(0, keys.shape[0], 128):
            cfg, stt = filters.auto_grow(cfg, stt, keys[i : i + 128])
        static_cfg, static_st = filters.make("qf", q=cfg.q, r=cfg.r)
        static_st = filters.insert(static_cfg, static_st, keys)
        probes = jnp.concatenate([keys[:2048], _keys(4, 8192, lo=2**31, hi=2**32)])
        got = filters.contains(cfg, stt, probes)
        want = filters.contains(static_cfg, static_st, probes)
        assert bool((got == want).all())

    def test_resize_io_is_charged(self):
        cfg, stt = filters.make("buffered_qf", ram_q=7, disk_q=10, p=26)
        keys = _keys(5, cfg.disk.capacity)
        for i in range(0, keys.shape[0], 64):
            cfg, stt = filters.auto_grow(cfg, stt, keys[i : i + 64])
        s = filters.stats(cfg, stt)
        assert int(s["resizes"]) >= 1
        # a resize re-streams the disk QF: bytes beyond the flush traffic
        assert float(s["seq_read_bytes"]) > 0


class TestHypothesisRoundTrips:
    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="needs hypothesis")
    @settings(deadline=None, max_examples=20)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.integers(1, 300),
        dq=st.integers(1, 3),
    )
    def test_grow_then_shrink_preserves_fingerprint_multiset(self, seed, n, dq):
        cfg = qf.QFConfig(q=9, r=12, slack=512)
        keys = _keys(seed, n)
        stt = qf.insert(cfg, qf.empty(cfg), keys)
        q0, r0, n0 = qf.extract(cfg, stt)
        up_cfg, up = qf.resize(cfg, stt, cfg.q + dq)
        down_cfg, down = qf.resize(up_cfg, up, cfg.q)
        assert down_cfg == cfg
        q1, r1, n1 = qf.extract(cfg, down)
        assert int(n0) == int(n1) == n
        assert bool((q0[:n] == q1[:n]).all())
        assert bool((r0[:n] == r1[:n]).all())

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="needs hypothesis")
    @settings(deadline=None, max_examples=15)
    @given(seed=st.integers(0, 2**31 - 1), steps=st.integers(1, 4))
    def test_no_false_negatives_across_any_growth_step(self, seed, steps):
        cfg, stt = filters.make("qf", q=8, r=16)
        keys = _keys(seed, 150)
        stt = filters.insert(cfg, stt, keys)
        for _ in range(steps):
            cfg, stt = filters.grow(cfg, stt)
            assert bool(filters.contains(cfg, stt, keys).all())
        assert int(filters.stats(cfg, stt)["n"]) == 150

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="needs hypothesis")
    @settings(deadline=None, max_examples=10)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_auto_grow_matches_static_answers(self, seed):
        cfg, stt = filters.make("qf", q=8, r=14)
        keys = _keys(seed, 4 * cfg.core.capacity)
        for i in range(0, keys.shape[0], 128):
            cfg, stt = filters.auto_grow(cfg, stt, keys[i : i + 128])
        scfg, sst = filters.make("qf", q=cfg.q, r=cfg.r)
        sst = filters.insert(scfg, sst, keys)
        probes = _keys(seed + 1, 2048, lo=2**31, hi=2**32)
        assert bool(
            (filters.contains(cfg, stt, probes) == filters.contains(scfg, sst, probes))
            .all()
        )


class TestPipelineGrowth:
    def test_dedup_pipeline_deepens_and_snapshots_across_growth(self):
        """The pipeline ingests through ``auto_grow``; a snapshot taken
        after the cascade deepened must restore into a fresh pipeline
        that still starts at the configured depth."""
        from repro.data.pipeline import DedupPipeline, PipelineConfig

        cfgp = PipelineConfig(
            seq_len=64, batch_size=2, duplicate_fraction=0.0, seed=9,
            dedup_ram_q=7, dedup_p=30, dedup_fanout=4, dedup_levels=1,
        )
        pipe = DedupPipeline(cfgp)
        rng = np.random.default_rng(3)
        all_ids = []
        for _ in range(24):  # ~1.5k uniques vs bottom capacity 384
            ids = rng.integers(0, 2**32, 64, dtype=np.uint64).astype(np.uint32)
            all_ids.append(ids)
            pipe._dedup(ids)
        assert pipe.filter_cfg.levels > 1  # grew through auto_grow
        assert not bool(
            filters.stats(pipe.filter_cfg, pipe.filter_state)["overflow"]
        )
        snap = pipe.snapshot()
        pipe2 = DedupPipeline(cfgp)
        pipe2.restore(snap)
        assert pipe2.filter_cfg == pipe.filter_cfg
        # every previously ingested id must now be recognized as a dup
        assert not pipe2._dedup(all_ids[0]).any()


class TestMergeOverflowRegression:
    def test_cascade_merge_of_two_half_full_cascades(self):
        """Two cascades whose level-0 is ~full: the old component-wise
        merge packed 2 * 3072 fingerprints into a level with 4096 + 1024
        slots and tripped ``overflow``; the streaming merge picks the
        smallest level that fits the union."""
        spec = dict(ram_q=10, p=30, fanout=4, levels=2)
        cfg, sa = filters.make("cascade", **spec)
        _, sb = filters.make("cascade", **spec)
        ka = _keys(10, 3100)
        kb = _keys(11, 3100, lo=2**30, hi=2**31)
        for i in range(0, 3100, 256):
            sa = filters.insert(cfg, sa, ka[i : i + 256])
            sb = filters.insert(cfg, sb, kb[i : i + 256])
        # the precondition of the regression: same-index levels > half full
        la = np.asarray(filters.stats(cfg, sa)["level_counts"])
        lb = np.asarray(filters.stats(cfg, sb)["level_counts"])
        cap0 = cfg.level_cfg(0).capacity
        assert la[0] > cap0 // 2 and lb[0] > cap0 // 2
        merged = filters.merge(cfg, sa, sb)
        s = filters.stats(cfg, merged)
        assert not bool(s["overflow"])
        assert int(s["n"]) == int(la.sum() + lb.sum()) + int(sa.q0.n) + int(sb.q0.n)
        assert bool(filters.contains(cfg, merged, ka).all())
        assert bool(filters.contains(cfg, merged, kb).all())

    def test_overflow_flag_survives_multi_merge_paths(self):
        """Regression: ``multi_merge`` dropped input overflow flags, so
        grow/merge of an already-overflowed structure reported healthy."""
        cfg, stt = filters.make("buffered_qf", ram_q=7, disk_q=10, p=26)
        stt = stt._replace(
            disk=stt.disk._replace(overflow=jnp.ones((), jnp.bool_))
        )
        cfg2, grown = filters.grow(cfg, stt)
        assert bool(filters.stats(cfg2, grown)["overflow"])
        ccfg, ca = filters.make("cascade", ram_q=7, p=30, fanout=4, levels=1)
        _, cb = filters.make("cascade", ram_q=7, p=30, fanout=4, levels=1)
        ca = ca._replace(q0=ca.q0._replace(overflow=jnp.ones((), jnp.bool_)))
        assert bool(filters.stats(ccfg, filters.merge(ccfg, ca, cb))["overflow"])

    def test_cascade_needs_resize_sees_q0_overshoot(self):
        """Regression: a batch overshooting Q0's design capacity could
        make every collapse impossible while ``needs_resize`` (which
        used the design capacity, not the actual count) stayed False."""
        cfg, stt = filters.make("cascade", ram_q=7, p=30, fanout=4, levels=1)
        big = _keys(50, 448)  # > bottom capacity 384: no collapse fits
        stt = filters.insert(cfg, stt, big)
        assert int(stt.q0.n) == 448  # stuck in Q0's slack
        assert bool(filters.needs_resize(cfg, stt))
        cfg, stt = filters.grow(cfg, stt)
        stt = filters.insert(cfg, stt, _keys(51, 64))
        assert bool(filters.contains(cfg, stt, big).all())
        assert not bool(filters.stats(cfg, stt)["overflow"])

    def test_buffered_merge_then_grow_recovers(self):
        """Merging two near-full buffered QFs oversubscribes the disk
        level; needs_resize flags it and one grow step restores the
        operating point with no false negatives."""
        spec = dict(ram_q=7, disk_q=10, p=26)
        cfg, sa = filters.make("buffered_qf", **spec)
        _, sb = filters.make("buffered_qf", **spec)
        ka = _keys(12, cfg.disk.capacity - 128)
        kb = _keys(13, cfg.disk.capacity - 128, lo=2**30, hi=2**31)
        for i in range(0, ka.shape[0], 64):
            sa = filters.insert(cfg, sa, ka[i : i + 64])
            sb = filters.insert(cfg, sb, kb[i : i + 64])
        merged = filters.merge(cfg, sa, sb)
        assert bool(filters.needs_resize(cfg, merged))
        cfg2, grown = filters.grow(cfg, merged)
        assert not bool(filters.needs_resize(cfg2, grown))
        assert bool(filters.contains(cfg2, grown, ka).all())
        assert bool(filters.contains(cfg2, grown, kb).all())


class TestSentinelClamp:
    def test_build_is_invariant_to_padding_amount(self):
        """Regression: the padding sentinel used to enter ``fq - idx``
        arithmetic, wrapping int32 for rows with idx >= 2.  The built
        planes must not depend on how much padding follows the valid
        prefix."""
        cfg = qf.QFConfig(q=6, r=8, slack=64)
        keys = _keys(20, 40)
        fq, fr = qf.fingerprints(cfg, keys)
        fq, fr = qf._pad_sort(fq, fr, jnp.ones((40,), jnp.bool_))
        built_tight = qf.build_sorted(cfg, fq, fr, 40)
        pad = 1000
        fq_p = jnp.concatenate([fq, jnp.full((pad,), qf.INT32_MAX, jnp.int32)])
        fr_p = jnp.concatenate([fr, jnp.full((pad,), qf.UINT32_MAX, jnp.uint32)])
        built_padded = qf.build_sorted(cfg, fq_p, fr_p, 40)
        for a, b in zip(built_tight, built_padded):
            assert bool(jnp.array_equal(a, b))
        assert not bool(built_padded.overflow)

    def test_kernel_build_matches_reference_with_heavy_padding(self):
        from repro.kernels import ops as kops

        cfg = qf.QFConfig(q=6, r=8, slack=64)
        keys = _keys(21, 30)
        fq, fr = qf.fingerprints(cfg, keys)
        fq, fr = qf._pad_sort(fq, fr, jnp.ones((30,), jnp.bool_))
        pad = 2048 - 30
        fq = jnp.concatenate([fq, jnp.full((pad,), qf.INT32_MAX, jnp.int32)])
        fr = jnp.concatenate([fr, jnp.full((pad,), qf.UINT32_MAX, jnp.uint32)])
        ref = qf.build_sorted(cfg, fq, fr, 30)
        ker = kops.build_sorted(cfg, fq, fr, 30)
        for a, b in zip(ref, ker):
            assert bool(jnp.array_equal(a, b))


class TestLayeredDeleteIO:
    def test_buffered_disk_delete_charges_io(self):
        from repro.filters import buffered as fb

        cfg, stt = filters.make("buffered_qf", ram_q=8, disk_q=12, p=24)
        keys = _keys(30, 512)
        stt = filters.insert(cfg, stt, keys)
        stt = fb.flush(cfg, stt)  # all 512 copies now disk-resident
        before = filters.stats(cfg, stt)
        stt = filters.delete(cfg, stt, keys[:100])
        after = filters.stats(cfg, stt)
        assert int(after["rand_page_reads"]) - int(before["rand_page_reads"]) == 100
        assert int(after["rand_page_writes"]) - int(before["rand_page_writes"]) == 100
        assert int(after["n"]) == 412

    def test_buffered_ram_delete_is_free(self):
        cfg, stt = filters.make("buffered_qf", ram_q=8, disk_q=12, p=24)
        keys = _keys(31, 100)
        stt = filters.insert(cfg, stt, keys)  # all in RAM, no flush at 100/192
        before = filters.stats(cfg, stt)
        stt = filters.delete(cfg, stt, keys[:50])
        after = filters.stats(cfg, stt)
        assert int(after["rand_page_reads"]) == int(before["rand_page_reads"])
        assert int(after["rand_page_writes"]) == int(before["rand_page_writes"])

    def test_cascade_disk_delete_charges_io(self):
        cfg, stt = filters.make("cascade", ram_q=8, p=26, fanout=2, levels=3)
        keys = _keys(32, 256)
        stt = filters.insert(cfg, stt, keys)  # 256 > cap0=192 -> collapsed
        assert int(filters.stats(cfg, stt)["nonempty_levels"]) >= 1
        assert int(stt.q0.n) == 0
        before = filters.stats(cfg, stt)
        stt = filters.delete(cfg, stt, keys[:64])
        after = filters.stats(cfg, stt)
        assert int(after["rand_page_reads"]) - int(before["rand_page_reads"]) == 64
        assert int(after["rand_page_writes"]) - int(before["rand_page_writes"]) == 64
        assert int(after["n"]) == 192
