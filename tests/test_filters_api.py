"""Conformance suite for the unified ``repro.filters`` protocol.

Every registered filter type runs the same insert / contains / delete /
merge invariants through the façade — call sites never touch a concrete
class.  The scan tests assert the tentpole property: a buffered-QF or
cascade ingest loop compiles into one ``jax.jit``/``lax.scan`` with
donated state and **zero** host transfers (``jax.transfer_guard``).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import filters

# name -> (registry name, spec); keys chosen so every structure sits at a
# sane load after N inserts and the QF-family fp rate is ~2^-10 or better
CASES = {
    "qf": ("qf", dict(q=11, r=10)),
    "qf_pallas": ("qf", dict(q=11, r=10, backend="pallas")),
    "bloom": ("bloom", dict(m_bits=1 << 16, k=6, counting=True)),
    "blocked_bloom": (
        "blocked_bloom",
        dict(m_bits=1 << 16, k=6, block_bits=1 << 12, counting=True),
    ),
    "buffered_qf": ("buffered_qf", dict(ram_q=8, disk_q=12, p=24)),
    "buffered_qf_pallas": (
        "buffered_qf",
        dict(ram_q=8, disk_q=12, p=24, backend="pallas"),
    ),
    "cascade": ("cascade", dict(ram_q=8, p=26, fanout=2, levels=3)),
    "cascade_frozen": (
        "cascade",
        dict(ram_q=8, p=26, fanout=2, levels=4, frozen_below=1),
    ),
    "sharded_qf": ("sharded_qf", dict(q=12, r=10, n_shards=1)),
    "steady_qf": ("steady_qf", dict(q=12, r=18)),
    "steady_qf_pallas": ("steady_qf", dict(q=12, r=18, backend="pallas")),
    # frozen family: capacity covers the merge test's 2N-key union
    "xor_fuse": ("xor_fuse", dict(capacity=2600, p=26)),
    "xor_fuse_pallas": ("xor_fuse", dict(capacity=2600, p=26, backend="pallas")),
}

# families whose façade ``insert`` raises (frozen / unsupported-k)
FROZEN = {"xor_fuse", "xor_fuse_pallas"}

N = 1024
CHUNK = 128  # buffered structures must ingest below their RAM capacity


def _keys(seed, n=N, lo=0, hi=2**31):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi, size=n, dtype=np.int64).astype(np.uint32))


def _mk(case):
    name, spec = CASES[case]
    return filters.make(name, **spec)


def _fill(cfg, state, keys):
    if not filters.supports(cfg, "insert"):  # frozen family: union batches
        from repro.filters import xor_fuse

        for i in range(0, keys.shape[0], CHUNK):
            state = xor_fuse.extend(cfg, state, keys[i : i + CHUNK])
        return state
    for i in range(0, keys.shape[0], CHUNK):
        state = filters.insert(cfg, state, keys[i : i + CHUNK])
    return state


@pytest.fixture(params=sorted(CASES), name="case")
def _case(request):
    return request.param


def test_registry_covers_every_name():
    assert set(filters.names()) == {name for name, _ in CASES.values()}
    for name, _ in CASES.values():
        impl = filters.by_name(name)
        assert impl.paper_section.startswith("§")


class TestConformance:
    def test_no_false_negatives(self, case):
        cfg, st = _mk(case)
        keys = _keys(1)
        st = _fill(cfg, st, keys)
        assert bool(filters.contains(cfg, st, keys).all())

    def test_fp_rate_bounded(self, case):
        cfg, st = _mk(case)
        st = _fill(cfg, st, _keys(2))
        absent = _keys(3, n=8192, lo=2**31, hi=2**32)
        assert float(filters.contains(cfg, st, absent).mean()) < 0.01

    def test_empty_contains_nothing(self, case):
        cfg, st = _mk(case)
        assert not bool(filters.contains(cfg, st, _keys(4, n=256)).any())

    def test_insert_valid_count_ignores_padding(self, case):
        cfg, st = _mk(case)
        keys = _keys(5, n=CHUNK)
        name = CASES[case][0]
        if case in FROZEN:
            # frozen family: the façade raises the structured capability
            # error (an UnsupportedOpError, still a NotImplementedError)
            with pytest.raises(filters.UnsupportedOpError) as ei:
                filters.insert(cfg, st, keys, k=CHUNK // 2)
            assert (ei.value.family, ei.value.op) == (name, "insert")
            return
        if name == "sharded_qf":
            with pytest.raises(NotImplementedError):
                filters.insert(cfg, st, keys, k=CHUNK // 2)
            return
        st = filters.insert(cfg, st, keys, k=CHUNK // 2)
        assert bool(filters.contains(cfg, st, keys[: CHUNK // 2]).all())
        s = filters.stats(cfg, st)
        if "n" in s:  # counted structures: padding must not inflate n
            assert int(s["n"]) == CHUNK // 2

    def test_delete_removes_one_copy(self, case):
        cfg, st = _mk(case)
        if not filters.supports(cfg, "delete"):
            pytest.skip(f"{CASES[case][0]} does not register delete")
        keys = _keys(6)
        st = _fill(cfg, st, keys)
        st = filters.delete(cfg, st, keys[: N // 2])
        # the untouched half must still be present (no false negatives)
        assert bool(filters.contains(cfg, st, keys[N // 2 :]).all())
        s = filters.stats(cfg, st)
        if "n" in s:
            assert int(s["n"]) == N // 2

    def test_layered_delete_spills_duplicate_copies(self):
        """Deleting more copies of a key than the top structure holds
        must remove the remainder from the structures below (regression:
        both batch occurrences used to target the RAM/Q0 copy)."""
        from repro.filters import buffered as fb

        key = jnp.asarray([42, 42], jnp.uint32)
        # buffered: one copy on disk (flushed), one in RAM
        cfg, st = filters.make("buffered_qf", ram_q=8, disk_q=12, p=24)
        st = filters.insert(cfg, st, key[:1])
        st = fb.flush(cfg, st)
        st = filters.insert(cfg, st, key[:1])
        assert int(filters.stats(cfg, st)["n"]) == 2
        st = filters.delete(cfg, st, key)
        assert int(filters.stats(cfg, st)["n"]) == 0
        assert not bool(filters.contains(cfg, st, key[:1]).any())
        # cascade: one copy collapsed to a level, one in Q0
        ccfg, cst = filters.make("cascade", ram_q=8, p=26, fanout=2, levels=3)
        cst = filters.insert(ccfg, cst, _keys(20, n=256))  # force a collapse
        cst = filters.insert(ccfg, cst, key[:1])
        before = int(filters.stats(ccfg, cst)["n"])
        cst = filters.insert(ccfg, cst, key[:1])
        cst = filters.delete(ccfg, cst, key)
        assert int(filters.stats(ccfg, cst)["n"]) == before - 1
        assert not bool(filters.contains(ccfg, cst, key[:1]).any())

    def test_supports_is_config_exact(self):
        plain, _ = filters.make("bloom", m_bits=1 << 12, k=4)
        counting, _ = filters.make("bloom", m_bits=1 << 12, k=4, counting=True)
        assert filters.supports("bloom", "delete")  # the family can
        assert not filters.supports(plain, "delete")  # this config can't
        assert filters.supports(counting, "delete")
        with pytest.raises(NotImplementedError):
            filters.delete(plain, filters.make("bloom", m_bits=1 << 12, k=4)[1],
                           jnp.arange(4, dtype=jnp.uint32))

    def test_merge_is_union(self, case):
        cfg, sa = _mk(case)
        if not filters.supports(cfg, "merge"):
            pytest.skip(f"{CASES[case][0]} does not register merge")
        _, sb = _mk(case)
        ka, kb = _keys(7), _keys(8, lo=2**30, hi=2**31)
        sa = _fill(cfg, sa, ka)
        sb = _fill(cfg, sb, kb)
        merged = filters.merge(cfg, sa, sb)
        assert bool(filters.contains(cfg, merged, ka).all())
        assert bool(filters.contains(cfg, merged, kb).all())
        s = filters.stats(cfg, merged)
        if "overflow" in s:
            assert not bool(s["overflow"])

    def test_stats_are_device_values(self, case):
        cfg, st = _mk(case)
        st = _fill(cfg, st, _keys(9, n=CHUNK))
        s = filters.stats(cfg, st)
        assert isinstance(s, dict) and s
        for v in s.values():
            assert isinstance(v, (jnp.ndarray, jax.Array, int, float))


class TestScannedIngest:
    """The tentpole acceptance: whole ingest loops under one jit + scan,
    flush/merge decisions on device, zero host transfers."""

    @pytest.mark.parametrize(
        "name,spec",
        [
            ("buffered_qf", dict(ram_q=8, disk_q=12, p=24)),
            ("cascade", dict(ram_q=8, p=26, fanout=2, levels=3)),
        ],
    )
    def test_scan_ingest_zero_host_syncs(self, name, spec):
        cfg, st = filters.make(name, **spec)
        batches = _keys(10, n=16 * CHUNK).reshape(16, CHUNK)

        def step(s, ks):
            return filters.insert(cfg, s, ks), None

        # 1) the step traces: a single scan, no concretization anywhere
        jaxpr = jax.make_jaxpr(lambda s, bs: jax.lax.scan(step, s, bs)[0])(
            st, batches
        )
        assert [e.primitive.name for e in jaxpr.jaxpr.eqns] == ["scan"]

        # 2) it executes with donated state and no device->host transfer
        ingest = jax.jit(
            lambda s, bs: jax.lax.scan(step, s, bs)[0], donate_argnums=0
        )
        st_dev = jax.device_put(st)
        b_dev = jax.device_put(batches)
        with jax.transfer_guard("disallow"):
            out = ingest(st_dev, b_dev)

        s = filters.stats(cfg, out)
        assert int(s["n"]) == batches.size
        assert int(s["flushes"]) > 0  # the cond/switch actually fired on device
        assert not bool(s["overflow"])
        assert bool(filters.contains(cfg, out, batches.reshape(-1)).all())

    def test_probe_accounts_page_reads_on_device(self):
        cfg, st = filters.make("buffered_qf", ram_q=8, disk_q=12, p=24)
        keys = _keys(11, n=512)
        st = _fill(cfg, st, keys)
        cfgc, stc = filters.make("cascade", ram_q=8, p=26, fanout=2, levels=3)
        stc = _fill(cfgc, stc, keys)
        for c, s0 in ((cfg, st), (cfgc, stc)):
            before = int(s0.io.rand_page_reads)
            s1, hit = filters.probe(c, s0, keys[:100])
            assert bool(hit.all())
            assert int(s1.io.rand_page_reads) >= before  # counted on device

    def test_probe_is_jittable(self):
        cfg, st = filters.make("buffered_qf", ram_q=8, disk_q=12, p=24)
        st = _fill(cfg, st, _keys(12, n=512))
        probe = jax.jit(lambda s, ks: filters.probe(cfg, s, ks))
        st2, hit = probe(st, _keys(12, n=512))
        assert bool(hit.all())
        assert int(st2.io.rand_page_reads) > 0
