"""Pallas kernel timings (interpret mode) vs jnp reference paths.

Interpret-mode wall time is NOT TPU performance — the derived column
records bytes-touched per op so the TPU projection (819 GB/s HBM
streaming) can be read off; correctness vs the oracle is asserted.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import quotient_filter as qf
from repro.kernels import ops

from .common import Row, keys_u32, time_fn


def run() -> list[Row]:
    rows = []
    rng = np.random.default_rng(11)
    cfg = qf.QFConfig(q=16, r=12, slack=2048)
    n = 40_000
    keys = keys_u32(rng, n)
    fq, fr = qf.fingerprints(cfg, keys)
    fq_s, fr_s = qf._pad_sort(fq, fr, jnp.ones(fq.shape, bool))

    t_core = time_fn(lambda: qf.build_sorted(cfg, fq_s, fr_s, n))
    t_kern = time_fn(lambda: ops.build_sorted(cfg, fq_s, fr_s, n))
    st = qf.build_sorted(cfg, fq_s, fr_s, n)
    st_k = ops.build_sorted(cfg, fq_s, fr_s, n)
    assert all(
        bool(jnp.all(a == b)) for a, b in zip(st, st_k)
    ), "kernel build mismatch"
    slot_bytes = cfg.total_slots * 7  # rem u32 + 3 bit-planes(bytes here)
    rows.append(Row("kernel_qf_build_interp", t_kern * 1e6,
                    f"jnp_ref_us={t_core*1e6:.0f};bytes={slot_bytes}"))

    probes = keys_u32(rng, 1 << 14)
    pq, pr = qf.fingerprints(cfg, probes)
    t_ref = time_fn(lambda: qf.lookup(cfg, st, pq, pr))
    t_k = time_fn(lambda: ops.lookup(cfg, st, pq, pr))
    got = ops.lookup(cfg, st, pq, pr)
    want = qf.lookup_exact(cfg, st, pq, pr)
    assert bool(jnp.all(got == want)), "kernel probe mismatch"
    rows.append(Row("kernel_qf_probe_interp", t_k * 1e6,
                    f"jnp_windowed_us={t_ref*1e6:.0f};queries=16384"))
    return rows
