"""Pallas kernel timings (interpret mode) vs jnp reference paths.

Interpret-mode wall time is NOT TPU performance — the derived column
records bytes-touched per op so the TPU projection (819 GB/s HBM
streaming) can be read off; correctness vs the oracle is asserted.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import fuse_filter as fuse
from repro.core import quotient_filter as qf
from repro.kernels import ops

from .common import Row, keys_u32, time_fn


def run() -> list[Row]:
    rows = []
    rng = np.random.default_rng(11)
    cfg = qf.QFConfig(q=16, r=12, slack=2048)
    n = 40_000
    keys = keys_u32(rng, n)
    fq, fr = qf.fingerprints(cfg, keys)
    fq_s, fr_s = qf._pad_sort(fq, fr, jnp.ones(fq.shape, bool))

    t_core = time_fn(lambda: qf.build_sorted(cfg, fq_s, fr_s, n))
    t_kern = time_fn(lambda: ops.build_sorted(cfg, fq_s, fr_s, n))
    st = qf.build_sorted(cfg, fq_s, fr_s, n)
    st_k = ops.build_sorted(cfg, fq_s, fr_s, n)
    assert all(
        bool(jnp.all(a == b)) for a, b in zip(st, st_k)
    ), "kernel build mismatch"
    slot_bytes = cfg.total_slots * 7  # rem u32 + 3 bit-planes(bytes here)
    rows.append(Row("kernel_qf_build_interp", t_kern * 1e6,
                    f"jnp_ref_us={t_core*1e6:.0f};bytes={slot_bytes}"))

    probes = keys_u32(rng, 1 << 14)
    pq, pr = qf.fingerprints(cfg, probes)
    # min-of-7: these feed the gated machine-invariant ratio rows
    t_ref = time_fn(lambda: qf.lookup(cfg, st, pq, pr), iters=7, agg=np.min)
    t_k = time_fn(lambda: ops.lookup(cfg, st, pq, pr), iters=7, agg=np.min)
    got = ops.lookup(cfg, st, pq, pr)
    want = qf.lookup_exact(cfg, st, pq, pr)
    assert bool(jnp.all(got == want)), "kernel probe mismatch"
    rows.append(Row("kernel_qf_probe_interp", t_k * 1e6,
                    f"jnp_windowed_us={t_ref*1e6:.0f};queries=16384"))
    # gated pallas/reference ratio: machine speed cancels in the
    # quotient, so the perf gate compares it to baseline WITHOUT the
    # median normalizer (see perf_gate.RATIO_PREFIXES)
    rows.append(Row("kernelratio_qf_probe", t_k / t_ref,
                    "pallas_over_ref;queries=16384"))

    # frozen-tier 3-gather probe: Pallas kernel vs the jnp reference
    fcfg = fuse.make_config(40_000, p=26, seed=3)
    fst = fuse.freeze_keys(fcfg, keys)
    fprobe = keys_u32(rng, 1 << 14)
    t_fref = time_fn(lambda: fuse.contains(fcfg, fst, fprobe), iters=7, agg=np.min)
    t_fk = time_fn(lambda: ops.fuse_contains(fcfg, fst, fprobe), iters=7, agg=np.min)
    got = ops.fuse_contains(fcfg, fst, fprobe)
    want = fuse.contains(fcfg, fst, fprobe)
    assert bool(jnp.all(got == want)), "fuse kernel probe mismatch"
    probe_bytes = 3 * 4 * (1 << 14)  # three u32 table reads per query
    rows.append(Row("kernel_fuse_probe_interp", t_fk * 1e6,
                    f"jnp_ref_us={t_fref*1e6:.0f};bytes={probe_bytes}"))
    rows.append(Row("kernelratio_fuse_probe", t_fk / t_fref,
                    "pallas_over_ref;queries=16384"))
    return rows
