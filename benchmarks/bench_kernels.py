"""Deployed-mode kernel timings + gated pallas/reference ratios.

Every row times the *deployed* kernel mode (``dispatch.default_mode()``:
Mosaic on real TPUs, the bit-exact XLA lowering everywhere else)
against the pure-jnp reference path that ``backend="pallas"`` replaces.
The ``kernelratio_*`` rows are machine-invariant quotients gated at an
absolute ceiling (``perf_gate.RATIO_MAX`` = 1.10): the pallas backend
must never be slower than the reference backend on the platform CI
runs on.  Interpret mode is a validation tool, not a production path —
it is exercised by ``tests/test_kernels.py`` and never timed here (the
pre-PR-7 rows timed it, which is where the committed "pallas loses by
8x" numbers came from).  Correctness vs the reference is asserted on
every pair before its ratio is reported.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro import filters
from repro.core import fuse_filter as fuse
from repro.core import quotient_filter as qf
from repro.kernels import dispatch, ops

from .common import Row, keys_u32, time_fn, time_pair


def _qf_rows(rng, mode) -> list[Row]:
    rows = []
    cfg = qf.QFConfig(q=16, r=12, slack=2048)
    n = 40_000
    keys = keys_u32(rng, n)
    fq, fr = qf.fingerprints(cfg, keys)
    fq_s, fr_s = qf._pad_sort(fq, fr, jnp.ones(fq.shape, bool))

    t_ref = time_fn(lambda: qf.build_sorted(cfg, fq_s, fr_s, n), iters=7, agg=np.min)
    t_dep = time_fn(lambda: ops.build_sorted(cfg, fq_s, fr_s, n), iters=7, agg=np.min)
    st = qf.build_sorted(cfg, fq_s, fr_s, n)
    st_k = ops.build_sorted(cfg, fq_s, fr_s, n)
    assert all(
        bool(jnp.all(a == b)) for a, b in zip(st, st_k)
    ), "kernel build mismatch"
    slot_bytes = cfg.total_slots * 7  # rem u32 + 3 bit-planes(bytes here)
    rows.append(Row("kernel_qf_build", t_dep * 1e6,
                    f"mode={mode};jnp_ref_us={t_ref*1e6:.0f};bytes={slot_bytes}"))

    probes = keys_u32(rng, 1 << 14)
    pq, pr = qf.fingerprints(cfg, probes)
    # min-of-7: these feed the gated machine-invariant ratio rows
    t_ref = time_fn(lambda: qf.lookup(cfg, st, pq, pr), iters=7, agg=np.min)
    t_dep = time_fn(lambda: ops.lookup(cfg, st, pq, pr), iters=7, agg=np.min)
    got = ops.lookup(cfg, st, pq, pr)
    want = qf.lookup_exact(cfg, st, pq, pr)
    assert bool(jnp.all(got == want)), "kernel probe mismatch"
    rows.append(Row("kernel_qf_probe", t_dep * 1e6,
                    f"mode={mode};jnp_windowed_us={t_ref*1e6:.0f};queries=16384"))
    # gated pallas/reference ratio: machine speed cancels in the
    # quotient, so the perf gate compares it to baseline WITHOUT the
    # median normalizer and caps it at RATIO_MAX absolutely
    rows.append(Row("kernelratio_qf_probe", t_dep / t_ref,
                    "pallas_over_ref;queries=16384"))

    # kernel-resident chunked build (PR 7): one fused span append vs the
    # per-chunk host-composed loop it replaced on the finish-path drain
    dst = qf.QFConfig(q=17, r=11, slack=2048)
    fqd, frd = qf._requotient(fq_s, fr_s, cfg, dst)
    C = 250  # 160 chunks over the 40k stream
    m1 = jnp.full((), -1, jnp.int32)

    def chunk_loop():
        st, lp, lf = qf.empty(dst), m1, m1
        for i in range(0, n, C):
            st, lp, lf = ops.build_chunk(
                dst, st, fqd[i : i + C], frd[i : i + C], jnp.int32(C), lp, lf
            )
        return st

    def span_drain():
        st, _, _ = ops.build_span(dst, qf.empty(dst), fqd, frd, jnp.int32(n), m1, m1)
        return st

    t_chunks = time_fn(chunk_loop, iters=3, agg=np.min)
    t_span = time_fn(span_drain, iters=7, agg=np.min)
    a, b = chunk_loop(), span_drain()
    assert all(bool(jnp.all(x == y)) for x, y in zip(a, b)), "span drain mismatch"
    rows.append(Row("kernel_build_span", t_span * 1e6,
                    f"mode={mode};chunk_loop_us={t_chunks*1e6:.0f};"
                    f"chunks={n // C};entries={n}"))
    rows.append(Row("kernelratio_build_chunk", t_span / t_chunks,
                    f"span_over_chunk_loop;chunks={n // C}"))
    return rows


def _fuse_rows(rng, mode) -> list[Row]:
    # frozen-tier 3-gather probe: deployed kernel path vs jnp reference
    rows = []
    keys = keys_u32(rng, 40_000)
    fcfg = fuse.make_config(40_000, p=26, seed=3)
    fst = fuse.freeze_keys(fcfg, keys)
    # 64k queries (not 16k): the two paths differ by a few us of eager
    # dispatch, which at a 70us probe is ~5% of the quotient — enough,
    # with timing jitter, to brush the 1.10 ceiling. At ~260us the row
    # measures the lookup lowering, not Python overhead; time_pair
    # interleaves the minima so machine drift cancels from the ratio.
    fprobe = keys_u32(rng, 1 << 16)
    t_ref, t_dep = time_pair(
        lambda: fuse.contains(fcfg, fst, fprobe),
        lambda: ops.fuse_contains(fcfg, fst, fprobe),
    )
    got = ops.fuse_contains(fcfg, fst, fprobe)
    want = fuse.contains(fcfg, fst, fprobe)
    assert bool(jnp.all(got == want)), "fuse kernel probe mismatch"
    probe_bytes = 3 * 4 * (1 << 16)  # three u32 table reads per query
    rows.append(Row("kernel_fuse_probe", t_dep * 1e6,
                    f"mode={mode};jnp_ref_us={t_ref*1e6:.0f};bytes={probe_bytes}"))
    rows.append(Row("kernelratio_fuse_probe", t_dep / t_ref,
                    "pallas_over_ref;queries=65536"))
    return rows


def _bloom_rows(rng, mode) -> list[Row]:
    # blocked-Bloom bin kernels: backend="pallas" vs backend="reference"
    # through the filter protocol (insert counts + AND-of-k contains)
    rows = []
    spec = dict(m_bits=1 << 20, k=4, block_bits=512)
    c_r, s0_r = filters.make("blocked_bloom", **spec)
    c_p, s0_p = filters.make("blocked_bloom", **spec, backend="pallas")
    bkeys = keys_u32(rng, 1 << 15)
    bprobes = keys_u32(rng, 1 << 14)
    t_ri = time_fn(lambda: filters.insert(c_r, s0_r, bkeys), iters=7, agg=np.min)
    t_pi = time_fn(lambda: filters.insert(c_p, s0_p, bkeys), iters=7, agg=np.min)
    s_r = filters.insert(c_r, s0_r, bkeys)
    s_p = filters.insert(c_p, s0_p, bkeys)
    assert bool(jnp.all(s_r.cells == s_p.cells)), "bloom insert mismatch"
    t_rc = time_fn(lambda: filters.contains(c_r, s_r, bprobes), iters=7, agg=np.min)
    t_pc = time_fn(lambda: filters.contains(c_p, s_p, bprobes), iters=7, agg=np.min)
    got_c = filters.contains(c_p, s_p, bprobes)
    want_c = filters.contains(c_r, s_r, bprobes)
    assert bool(jnp.all(got_c == want_c)), "bloom contains mismatch"
    rows.append(Row("kernel_bloom_block", (t_pi + t_pc) * 1e6,
                    f"mode={mode};ref_us={(t_ri + t_rc)*1e6:.0f};"
                    f"inserts=32768;queries=16384"))
    rows.append(Row("kernelratio_bloom_block", (t_pi + t_pc) / (t_ri + t_rc),
                    "pallas_over_ref;insert_plus_contains"))
    return rows


def run() -> list[Row]:
    rng = np.random.default_rng(11)
    mode = dispatch.default_mode()
    return _qf_rows(rng, mode) + _fuse_rows(rng, mode) + _bloom_rows(rng, mode)
