"""Benchmark harness: one section per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--only NAME[,NAME...]]
``--only`` takes a comma-separated list of substrings; a module runs if
any of them matches its name (e.g. ``--only bench_resize,bench_incremental``
is what the CI perf gate runs).  Prints ``name,us_per_call,derived``
CSV rows (per the scaffold contract) and writes
experiments/bench_results.csv incrementally — rows are appended and
flushed as each module finishes, so one crashing bench cannot lose the
rows of the modules that already completed.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


MODULES = [
    "bench_inram",      # Table 1a
    "bench_ssd",        # Table 1b + Figs 7/8 (small + large)
    "bench_fprate",     # Figs 1/2
    "bench_clusters",   # Fig 4
    "bench_occupancy",  # Fig 6
    "bench_fanout",     # Fig 9 / §5.3
    "bench_resize",     # §3 resizing: doubling vs rebuild + growth schedules
    "bench_incremental",  # blocking vs amortized growth (the headline curve)
    "bench_steady_state",  # steady-state insert tail under mixed traffic
    "bench_kernels",    # deployed-mode kernels + gated pallas/ref ratios
    "bench_cascade_probe",  # fused multi-level probe vs per-level walk
    "bench_xor_fuse",   # frozen (binary-fuse) cold tier vs QF levels
    "bench_analysis",   # static-analysis pass wall-time (CI analysis job)
]

OUT_PATH = os.path.join("experiments", "bench_results.csv")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated name substrings")
    args = ap.parse_args()
    wanted = [w for w in (args.only or "").split(",") if w]

    import importlib

    os.makedirs("experiments", exist_ok=True)
    print("name,us_per_call,derived")
    with open(OUT_PATH, "w") as f:
        f.write("name,us_per_call,derived\n")
        f.flush()
        for modname in MODULES:
            if wanted and not any(w in modname for w in wanted):
                continue
            t0 = time.time()
            mod = importlib.import_module(f"benchmarks.{modname}")
            rows = mod.run()
            for r in rows:
                print(r.csv(), flush=True)
                f.write(r.csv() + "\n")
            f.flush()
            print(f"# {modname} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
