"""Paper Fig 6: in-RAM QF vs BF throughput as occupancy grows.

The paper's signature curves: QF insert/lookup throughput degrades as
clusters grow toward full; BF is flat-ish.  Derived column records the
degradation ratio 90%-vs-30% occupancy.
"""

from __future__ import annotations

import numpy as np

from repro.core import bloom, quotient_filter as qf

from .common import Row, keys_u32, time_fn

Q = 16
BATCH = 1 << 13


def run() -> list[Row]:
    rows = []
    rng = np.random.default_rng(5)
    cfg = qf.QFConfig(q=Q, r=10, slack=4096, max_load=0.95)
    k = 9
    m_bits = int((1 << Q) * 0.95 * k / np.log(2))
    bcfg = bloom.BloomConfig(m_bits=m_bits, k=k)

    st = qf.empty(cfg)
    bits = bloom.empty(bcfg)
    probes = keys_u32(rng, 1 << 14, lo=2**31)
    qf_lookup_t, bf_lookup_t = {}, {}
    for pct in (30, 60, 90):
        target = int((1 << Q) * pct / 100)
        while int(st.n) < target:
            batch = keys_u32(rng, min(BATCH, target - int(st.n)))
            st = qf.insert(cfg, st, batch)
            bits = bloom.insert(bcfg, bits, batch)
        t_qf = time_fn(lambda: qf.contains(cfg, st, probes)) / probes.shape[0]
        t_bf = time_fn(lambda: bloom.lookup(bcfg, bits, probes)) / probes.shape[0]
        qf_lookup_t[pct] = t_qf
        bf_lookup_t[pct] = t_bf
        rows.append(Row(f"occupancy_lookup_qf_{pct}pct", t_qf * 1e6,
                        f"ops/s={1/t_qf:.0f}"))
        rows.append(Row(f"occupancy_lookup_bf_{pct}pct", t_bf * 1e6,
                        f"ops/s={1/t_bf:.0f}"))
    rows.append(Row("occupancy_qf_degradation", 0.0,
                    f"lookup_90/30={qf_lookup_t[90]/qf_lookup_t[30]:.2f}"))
    return rows
