"""Compiled-cost roofline profile of the deployed kernel-layer ops.

Usage::

    PYTHONPATH=src python -m benchmarks.kernel_profile \
        [--out experiments/kernel_roofline.json]

For each kernel-layer op this lowers + compiles the deployed lowering
(``dispatch.default_mode()``) and wraps the optimized module's
``cost_analysis`` into :class:`repro.launch.roofline.Roofline` —
FLOPs, bytes streamed, and the v5e HBM-projection time a bandwidth-
bound TPU run would need.  The CPU wall-clock ratios in
``bench_kernels``/``bench_cascade_probe`` say "never slower here";
this artifact says what the same passes cost on the accelerator's
roofline.  The perf-gate CI job uploads the JSON as the
``kernel-roofline`` artifact next to the bench CSV.
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import fuse_filter as fuse
from repro.core import quotient_filter as qf
from repro.kernels import dispatch, ops
from repro.launch.roofline import kernel_roofline

from .common import keys_u32

OUT_PATH = os.path.join("experiments", "kernel_roofline.json")


def profiles() -> dict:
    rng = np.random.default_rng(17)
    out = {}

    # -- QF build + probe (the §3 streaming passes) ---------------------
    cfg = qf.QFConfig(q=16, r=12, slack=2048)
    n = 40_000
    fq, fr = qf.fingerprints(cfg, keys_u32(rng, n))
    fq_s, fr_s = qf._pad_sort(fq, fr, jnp.ones(fq.shape, bool))
    out["qf_build_sorted"] = kernel_roofline(
        lambda a, b: ops.build_sorted(cfg, a, b, n), fq_s, fr_s
    )
    st = qf.build_sorted(cfg, fq_s, fr_s, n)
    pq, pr = qf.fingerprints(cfg, keys_u32(rng, 1 << 14))
    out["qf_lookup"] = kernel_roofline(
        lambda a, b: ops.lookup(cfg, st, a, b), pq, pr
    )

    # -- kernel-resident span build (the finish-path drain) -------------
    dst = qf.QFConfig(q=17, r=11, slack=2048)
    fqd, frd = qf._requotient(fq_s, fr_s, cfg, dst)
    m1 = jnp.full((), -1, jnp.int32)
    out["qf_build_span"] = kernel_roofline(
        lambda a, b: ops.build_span(dst, qf.empty(dst), a, b, jnp.int32(n), m1, m1),
        fqd,
        frd,
    )

    # -- frozen-tier 3-gather probe --------------------------------------
    fcfg = fuse.make_config(40_000, p=26, seed=3)
    fst = fuse.freeze_keys(fcfg, keys_u32(rng, 40_000))
    out["fuse_contains"] = kernel_roofline(
        lambda k: ops.fuse_contains(fcfg, fst, k), keys_u32(rng, 1 << 14)
    )

    # -- fused multi-level cascade probe ---------------------------------
    from repro import filters

    ccfg, cst = filters.make(
        "cascade", ram_q=8, p=26, fanout=2, levels=3, backend="pallas",
        frozen_below=2,
    )
    ckeys = keys_u32(rng, 3000)
    for i in range(0, 3000, 128):
        cst = filters.insert(ccfg, cst, ckeys[i : i + 128])
    out["cascade_probe_fused"] = kernel_roofline(
        lambda k: filters.contains(ccfg, cst, k), keys_u32(rng, 1 << 13)
    )

    # -- blocked-Bloom bin kernels ---------------------------------------
    bcfg, bst = filters.make(
        "blocked_bloom", m_bits=1 << 20, k=4, block_bits=512, backend="pallas"
    )
    bkeys = keys_u32(rng, 1 << 15)
    out["bloom_block_insert"] = kernel_roofline(
        lambda k: filters.insert(bcfg, bst, k), bkeys
    )
    bst = filters.insert(bcfg, bst, bkeys)
    out["bloom_block_contains"] = kernel_roofline(
        lambda k: filters.contains(bcfg, bst, k), keys_u32(rng, 1 << 14)
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()

    payload = {
        "comment": (
            "Roofline terms (v5e constants) of the deployed kernel-layer "
            "ops, from compiled-module cost_analysis; t_memory_s is the "
            "HBM-streaming projection for these bandwidth-bound passes."
        ),
        "backend": jax.default_backend(),
        "kernel_mode": dispatch.default_mode(),
        "ops": {name: rl.as_dict() for name, rl in profiles().items()},
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {len(payload['ops'])} op profiles -> {args.out}")
    for name, d in payload["ops"].items():
        print(
            f"{name:24s} flops={d['flops_per_device']:.3e} "
            f"bytes={d['bytes_per_device']:.3e} "
            f"t_mem={d['t_memory_s']*1e6:.1f}us bound={d['bound']}"
        )


if __name__ == "__main__":
    main()
