"""Paper Figs 1/2: false-positive rate vs bits per element, QF vs BF.

QF: fp ~= alpha * 2^-r at (r + 3) bits/slot = (r + 3)/alpha bits/elt.
BF: fp = (1 - e^{-kn/m})^k at optimal k.  Empirical rates must match
the analytic curves; derived column = empirical/analytic ratio.
"""

from __future__ import annotations

import numpy as np

from repro.core import bloom, quotient_filter as qf

from .common import Row, keys_u32

Q = 14
LOAD = 0.75
N_PROBES = 400_000


def run() -> list[Row]:
    rows = []
    rng = np.random.default_rng(7)
    n = int((1 << Q) * LOAD)
    keys = keys_u32(rng, n)
    probes = keys_u32(rng, N_PROBES, lo=2**31)

    for r in (4, 6, 8, 10, 12):
        cfg = qf.QFConfig(q=Q, r=r, slack=2048)
        st = qf.insert(cfg, qf.empty(cfg), keys)
        fp = float(qf.contains(cfg, st, probes).mean())
        analytic = 1 - np.exp(-n / 2 ** (Q + r))
        bits_per_elt = (r + 3) / LOAD
        rows.append(
            Row(
                f"fprate_qf_r{r}",
                bits_per_elt,  # (column reused: bits/element)
                f"empirical={fp:.2e};analytic={analytic:.2e};"
                f"ratio={fp / max(analytic, 1e-12):.2f}",
            )
        )

    for bits in (6, 9, 12, 15):
        k = bloom.optimal_k(bits)
        m_bits = n * bits
        bcfg = bloom.BloomConfig(m_bits=m_bits, k=k)
        bbits = bloom.insert(bcfg, bloom.empty(bcfg), keys)
        fp = float(bloom.lookup(bcfg, bbits, probes).mean())
        analytic = (1 - np.exp(-k * n / m_bits)) ** k
        rows.append(
            Row(
                f"fprate_bf_{bits}bpe",
                float(bits),
                f"empirical={fp:.2e};analytic={analytic:.2e};"
                f"ratio={fp / max(analytic, 1e-12):.2f}",
            )
        )
    return rows
