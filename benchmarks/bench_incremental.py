"""The paper's headline "don't thrash" curve: blocking vs amortized growth.

One experiment, run twice over the *same* key stream arriving in small
serving-sized batches at a table sitting at its max-load point:

* **blocking** — ``filters.auto_grow``: the batch that trips the high
  watermark pays the whole stop-the-world re-stream (extract +
  requotient + rebuild of the doubled table) before it returns.
* **incremental** — ``filters.auto_scale``: the same trip opens an
  ``filters.incremental_resize`` migration; every subsequent batch
  moves one bounded chunk of quotient runs and lands its fresh keys in
  the small side buffer, so no single insert ever touches the full
  table.

Per-call wall latency is recorded for every batch; the rows report the
p99 over the *growth window* — the calls that perform structural work
(for blocking, the call where the table doubled; for incremental, the
calls issued while the migration was in flight).  The acceptance bar
for this repo is ``p99_blocking / p99_incremental >= 5``.

Methodology: both drivers are deterministic, so each variant replays
the identical (state, stream) sequence ``REPS`` times and each call
index keeps its *minimum* latency across replays — the ``timeit``
min-of-repeats discipline applied per call.  This isolates the
algorithmic latency: shared 2-vCPU runners impose ~40-70 ms scheduler
/allocator stalls on ~10% of *all* sub-millisecond calls (measured on
a bare ``jit(x + 1)`` loop), which would otherwise report the host,
not the filter.  The first replay doubles as the jit warmup.

The one-off settle pass that folds the side buffer in at the end of a
migration is reported separately (``incr_finish``) — it is a sort-free
two-stream merge, cheaper than the blocking re-stream it replaces, and
it happens once per doubling instead of gating a victim batch on the
full sort.
"""

from __future__ import annotations

import time

import numpy as np
import jax

from repro import filters
from repro.filters import incremental_resize

from .common import Row, keys_u32

Q = 16  # starting quotient bits: ~49k keys in table when the trigger trips
P = 30  # fingerprint bits
BATCH = 8  # serving-sized insert batches
CHUNK = 240  # migration chunk: cap/CHUNK ~ 205-batch growth window, so the
#              two one-off calls (open + settle) sit beyond the p99 index
BUF_Q = 12  # side buffer: holds the ~205 * BATCH fresh keys of one drain
REPS = 4  # replays per variant; per-call latency = min across replays


def _filled(seed=3):
    cfg, st = filters.make("qf", q=Q, r=P - Q)
    fill = keys_u32(np.random.default_rng(seed), cfg.core.capacity - BATCH)
    st = filters.insert(cfg, st, fill)
    return cfg, jax.block_until_ready(st)


def _stream(rng, n_batches):
    return [
        keys_u32(rng, BATCH, lo=2**31, hi=2**32) for _ in range(n_batches)
    ]


def _drive(cfg, st, stream, step, stop_after_growth=None):
    """Run the stream; return (latencies_s, growth_mask)."""
    lats, growth = [], []
    tail = None
    for batch in stream:
        was_migrating = incremental_resize.is_migrating(cfg)
        q_before = cfg.q if hasattr(cfg, "q") else None
        t0 = time.perf_counter()
        cfg, st = step(cfg, st, batch)
        jax.block_until_ready(st)
        lats.append(time.perf_counter() - t0)
        now_migrating = incremental_resize.is_migrating(cfg)
        grew_blocking = (
            not was_migrating
            and not now_migrating
            and hasattr(cfg, "q")
            and cfg.q != q_before
        )
        growth.append(was_migrating or now_migrating or grew_blocking)
        if stop_after_growth is not None and grew_blocking and tail is None:
            tail = stop_after_growth
        if tail is not None:
            tail -= 1
            if tail <= 0:
                break
    return np.asarray(lats), np.asarray(growth)


def _min_of_reps(stream, step, stop_after_growth=None):
    """Deterministic replays; per-call min latency (rep 0 = jit warmup)."""
    best = win = None
    for _ in range(REPS):
        cfg, st = _filled()
        lats, growth = _drive(cfg, st, stream, step, stop_after_growth)
        if best is None:
            best, win = lats, growth
        else:
            n = min(len(best), len(lats))
            assert (win[:n] == growth[:n]).all(), "replay diverged"
            best, win = np.minimum(best[:n], lats[:n]), win[:n]
    return best, win


def run() -> list[Row]:
    rng = np.random.default_rng(7)
    cap = filters.make("qf", q=Q, r=P - Q)[0].core.capacity
    n_batches = cap // CHUNK + 16  # covers the full drain + slack
    stream = _stream(rng, n_batches)

    def blocking(c, s, b):
        return filters.auto_grow(c, s, b)

    def incremental(c, s, b):
        return filters.auto_scale(c, s, b, chunk=CHUNK, buf_q=BUF_Q)

    # blocking: auto_grow pays the doubling inside one insert call; its
    # window is that call, so the replays stop shortly after it
    lat_b, win_b = _min_of_reps(stream, blocking, stop_after_growth=3)
    assert win_b.any(), "blocking run never grew — resize the experiment"

    # incremental: auto_scale amortizes it across the whole drain
    lat_i, win_i = _min_of_reps(stream, incremental)
    assert win_i.any(), "incremental run never migrated — resize the experiment"

    # isolate the settle pass: finish() on a half-drained migration
    # (first rep warms the jit cache, later reps measure)
    settle_us = np.inf
    for rep in range(2):
        mcfg, ms = incremental_resize.begin(*_filled(), chunk=CHUNK, buf_q=BUF_Q)
        for b in stream[: n_batches // 2]:
            ms = filters.insert(mcfg, ms, b)
        jax.block_until_ready(ms)
        t0 = time.perf_counter()
        _, settled = incremental_resize.finish(mcfg, ms)
        jax.block_until_ready(settled)
        if rep > 0:
            settle_us = min(settle_us, (time.perf_counter() - t0) * 1e6)

    p99_b = float(np.percentile(lat_b[win_b], 99) * 1e6)
    p99_i = float(np.percentile(lat_i[win_i], 99) * 1e6)
    p50_b = float(np.percentile(lat_b[win_b], 50) * 1e6)
    p50_i = float(np.percentile(lat_i[win_i], 50) * 1e6)
    max_b = float(lat_b[win_b].max() * 1e6)
    max_i = float(lat_i[win_i].max() * 1e6)
    speedup = p99_b / p99_i

    return [
        Row(
            "incr_growth_p99_blocking",
            p99_b,
            f"p50={p50_b:.0f}us;max={max_b:.0f}us;window={int(win_b.sum())}",
        ),
        Row(
            "incr_growth_p99_incremental",
            p99_i,
            f"p50={p50_i:.0f}us;max={max_i:.0f}us;window={int(win_i.sum())};"
            f"chunk={CHUNK};p99_speedup={speedup:.1f}x",
        ),
        Row(
            "incr_finish",
            settle_us,
            "one sort-free buffer fold per doubling (off the p99 path)",
        ),
    ]
