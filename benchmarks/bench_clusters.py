"""Paper Fig 4: cluster-size distribution at alpha in {0.5, 0.75, 0.9}.

Clusters are maximal runs of non-empty slots.  The paper reports the
distribution mass at small sizes (alpha=0.5: 99% < 24) and the
theoretical mean < 1/(1 - alpha*e^{1-alpha}).
"""

from __future__ import annotations

import numpy as np

from repro.core import quotient_filter as qf

from .common import Row, keys_u32

Q = 16


def run() -> list[Row]:
    rows = []
    rng = np.random.default_rng(4)
    for alpha in (0.5, 0.75, 0.9):
        cfg = qf.QFConfig(q=Q, r=10, slack=4096, max_load=alpha)
        n = int((1 << Q) * alpha)
        st = qf.insert(cfg, qf.empty(cfg), keys_u32(rng, n))
        nonempty = np.asarray(st.occ | st.shf)
        # cluster lengths = runs of consecutive nonempty slots
        changes = np.flatnonzero(np.diff(nonempty.astype(np.int8)))
        edges = np.concatenate([[-1], changes, [len(nonempty) - 1]])
        lengths = []
        state = nonempty[0]
        for a, b in zip(edges[:-1], edges[1:]):
            if state:
                lengths.append(b - a)
            state = not state
        lengths = np.asarray(lengths)
        mean = float(lengths.mean())
        p99 = float(np.percentile(lengths, 99))
        bound = 1.0 / (1 - alpha * np.exp(1 - alpha))
        rows.append(
            Row(
                f"clusters_alpha{alpha}",
                mean,  # column = mean cluster length
                f"p99={p99:.0f};max={lengths.max()};"
                f"analytic_mean_bound={bound:.1f};ok={mean < bound}",
            )
        )
    return rows
