"""Fused multi-level cascade probe vs the per-level reference walk.

A probe against a cascade must consult Q0 plus every non-empty level.
The reference backend re-fingerprints the batch per level and walks the
structures one by one; the pallas backend's ``ops.cascade_lookup``
hashes once, sorts once (the canonical fingerprint order is
simultaneously sorted for every level's quotient — requotienting is
monotone), and probes all unfrozen levels' windows in ONE grid, folding
frozen (binary-fuse) levels in via their 3-gather pass.

The gated ``kernelratio_cascade_probe`` row is the fused/deployed time
over the reference walk on a 4-level mixed-frozen stack at 16k probes —
capped absolutely at ``perf_gate.RATIO_MAX`` so the fused pass can
never silently regress behind the per-level path it replaces.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import filters
from repro.kernels import dispatch

from .common import Row, keys_u32, time_fn

RAM_Q = 9
P = 28
LEVELS = 4
FROZEN_BELOW = 2  # levels 2..3 demoted to binary-fuse form
N_KEYS = 20_000
N_PROBES = 1 << 14


def _grown(rng, backend):
    cfg, st = filters.make(
        "cascade",
        ram_q=RAM_Q,
        p=P,
        fanout=4,
        levels=LEVELS,
        backend=backend,
        frozen_below=FROZEN_BELOW,
    )
    keys = keys_u32(rng, N_KEYS)
    for i in range(0, N_KEYS, 512):
        st = filters.insert(cfg, st, keys[i : i + 512])
    return cfg, jax.block_until_ready(st), keys


def run() -> list[Row]:
    rng = np.random.default_rng(23)
    mode = dispatch.default_mode()
    cfg_p, st, keys = _grown(rng, "pallas")
    cfg_r = cfg_p._replace(backend="reference")
    probes = jnp.concatenate(
        [keys[: N_PROBES // 2], keys_u32(rng, N_PROBES // 2)]
    )

    # jit both sides: the ratio should compare the fused single-grid
    # probe against the per-level *algorithm*, not against the eager
    # dispatch overhead of walking five structures op by op
    f_ref = jax.jit(lambda s, p: filters.contains(cfg_r, s, p))
    f_fused = jax.jit(lambda s, p: filters.contains(cfg_p, s, p))
    t_ref = time_fn(lambda: f_ref(st, probes), iters=7, agg=np.min)
    t_fused = time_fn(lambda: f_fused(st, probes), iters=7, agg=np.min)
    got = filters.contains(cfg_p, st, probes)
    want = filters.contains(cfg_r, st, probes)
    assert bool(jnp.all(got == want)), "fused cascade probe mismatch"

    ns = [int(s.n) for s in st.levels]
    nonempty = sum(1 for n in ns if n > 0)
    rows = [
        Row(
            "cascade_probe_fused",
            t_fused * 1e6,
            f"mode={mode};per_level_ref_us={t_ref*1e6:.0f};"
            f"levels={LEVELS};frozen_below={FROZEN_BELOW};"
            f"nonempty={nonempty};queries={N_PROBES}",
        ),
        Row(
            "kernelratio_cascade_probe",
            t_fused / t_ref,
            f"fused_over_per_level;levels={LEVELS};queries={N_PROBES}",
        ),
    ]
    return rows
