"""LSM-style steady state: per-op insert latency under mixed traffic.

``bench_incremental`` measures the *growth* tail — what a doubling
costs.  This bench measures the other tail the paper's buffered QF
exists to remove (§4): the **steady-state insert path** itself.  A flat
QF insert is an in-place run rewrite over the whole table, so every
insert pays O(table) even when no resize is near; the ``steady_qf``
family lands the batch in its small resident buffer and moves one
bounded settle chunk instead, so the per-op cost is O(buffer + chunk).

One deterministic mixed op stream (insert / probe / delete in a fixed
pattern, serving-sized ``BATCH``-key calls) is replayed against every
family from the same warm starting state:

* ``flat`` — the plain QF, the pre-steady in-place baseline;
* ``steady`` — flat table + resident write buffer + background settle;
* ``buffered`` — the paper's RAM-buffer-over-flash layout;
* ``cascade`` / ``cascade_frozen`` — the multi-level layout, all-QF
  and with the binary-fuse cold tier (frozen skips the delete ops —
  the cold tier cannot delete).

Only the *insert* calls are ranked; probes and deletes are context
(deletes are off the hot path by design — ``steady_qf.delete`` settles
first).  Methodology matches ``bench_incremental``: each replay starts
from a copy of the same prefilled state, and each call index keeps its
minimum latency across ``REPS`` replays, so shared-runner scheduler
stalls do not masquerade as filter work.

Gate rows: ``p99ratio_*`` = family p99 / flat p99, machine-invariant
quotients gated against **absolute ceilings** in ``perf_gate.py`` (no
median normalizer — like ``kernelratio_*``).  The steady ceiling of
0.2 is this PR's acceptance bar: steady-state p99 at least 5x below
the in-place path.  The bench itself asserts the no-stop-the-world
bound: no steady/buffered/cascade insert call — settle ticks, buffer
folds and merge-downs included — may cost more than the flat
baseline's own *routine* p99, i.e. structural work never produces an
op worse than what the in-place path pays on every call.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import filters

from .common import Row, keys_u32

Q = 16  # flat table quotient bits: big enough that O(table) >> O(buffer)
P = 30  # fingerprint bits
BATCH = 8  # serving-sized op batches
N_OPS = 192  # ops per replay (~144 inserts: p99 = 2nd-worst insert)
REPS = 3  # replays; per-call latency = min across replays
PREFILL = 0.7  # warm-start load of the flat table
CHUNK = 512  # steady settle chunk (the bounded per-op structural work)

# family -> (registry name, make() spec); every variant holds the same
# ~2^16-slot, p=30 key space so the op stream is identical across them
FAMILIES = {
    "flat": ("qf", dict(q=Q, r=P - Q)),
    # buf/watermark sized so settles open INSIDE the timed window (the
    # rare deletes settle as a side effect; a roomy buffer would hide
    # every settle tick behind them and the bench would prove nothing)
    "steady": (
        "steady_qf",
        dict(q=Q, r=P - Q, buf_q=10, chunk=CHUNK, settle_load=0.25),
    ),
    "buffered": ("buffered_qf", dict(ram_q=11, disk_q=Q, p=P)),
    "cascade": ("cascade", dict(ram_q=11, p=P, fanout=4, levels=3)),
    "cascade_frozen": (
        "cascade",
        dict(ram_q=11, p=P, fanout=4, levels=3, frozen_below=1),
    ),
}


def _op_kind(i: int) -> str:
    """Fixed mixed-traffic pattern: mostly inserts, probes interleaved,
    a rare delete (real eviction cadence is orders below ingest)."""
    if i % 48 == 13:
        return "delete"
    if i % 4 == 3:
        return "probe"
    return "insert"


def _stream(rng, prefill_keys):
    """One deterministic op list shared by every family and replay."""
    ops = []
    for i in range(N_OPS):
        kind = _op_kind(i)
        if kind == "delete":
            # delete keys known to be present (from the prefill)
            idx = rng.integers(0, prefill_keys.shape[0], size=BATCH)
            ops.append((kind, jnp.asarray(np.asarray(prefill_keys)[idx])))
        else:
            ops.append((kind, keys_u32(rng, BATCH, lo=2**31, hi=2**32)))
    return ops


def _prefilled(name, spec, prefill):
    cfg, st = filters.make(name, **spec)
    # chunked prefill (chunks fit every family's RAM tier): a cascade /
    # buffered build folds level by level as it would in production
    for i in range(0, prefill.shape[0], 1024):
        st = filters.insert(cfg, st, prefill[i : i + 1024])
    if name == "steady_qf":
        # quiesce: every replay starts from an idle (settled) table, so
        # the settle ticks the stream provokes are its own, not relics
        from repro.filters import steady

        st = steady.settle_all(cfg, st)
    return cfg, jax.block_until_ready(st)


def _drive(cfg, st0, ops, can_delete):
    """Replay the op stream once; per-op latency + insert mask."""
    # steady's insert step donates its state buffers: replay from a copy
    st = jax.tree_util.tree_map(jnp.copy, st0)
    lats, is_insert = [], []
    for kind, keys in ops:
        if kind == "delete" and not can_delete:
            kind = "probe"  # frozen cold tier: eviction ages out via merges
        t0 = time.perf_counter()
        if kind == "insert":
            st = filters.insert(cfg, st, keys)
            jax.block_until_ready(st)
        elif kind == "probe":
            jax.block_until_ready(filters.contains(cfg, st, keys))
        else:
            st = filters.delete(cfg, st, keys)
            jax.block_until_ready(st)
        lats.append(time.perf_counter() - t0)
        is_insert.append(kind == "insert")
    return np.asarray(lats), np.asarray(is_insert), st


def _min_of_reps(cfg, st0, ops, can_delete):
    best = mask = st = None
    for _ in range(REPS):  # rep 0 doubles as the jit warmup
        lats, m, st = _drive(cfg, st0, ops, can_delete)
        if best is None:
            best, mask = lats, m
        else:
            assert (mask == m).all(), "replay diverged"
            best = np.minimum(best, lats)
    return best[mask], st


def run() -> list[Row]:
    rng = np.random.default_rng(11)
    cap = filters.make("qf", q=Q, r=P - Q)[0].core.capacity
    prefill = keys_u32(rng, int(cap * PREFILL))
    ops = _stream(rng, prefill)

    ins_lats = {}
    for label, (name, spec) in FAMILIES.items():
        cfg, st0 = _prefilled(name, spec, prefill)
        ins_lats[label], st = _min_of_reps(
            cfg, st0, ops, can_delete=filters.supports(cfg, "delete")
        )
        if label == "steady":
            # the timed window must exercise the settle machinery, not
            # coast on deletes quietly settling the buffer for it
            settles = int(filters.stats(cfg, st)["settles"])
            assert settles > len(
                [1 for i in range(N_OPS) if _op_kind(i) == "delete"]
            ), f"steady run settled only {settles}x — watermark never tripped"

    def pct(a, q):
        return float(np.percentile(a, q) * 1e6)

    p99_flat = pct(ins_lats["flat"], 99)
    rows = []
    for label, lats in ins_lats.items():
        p50, p99, mx = pct(lats, 50), pct(lats, 99), float(lats.max() * 1e6)
        rows.append(
            Row(
                f"steadystate_{label}_insert_p99",
                p99,
                f"p50={p50:.0f}us;max={mx:.0f}us;ops={len(lats)}",
            )
        )
        if label != "flat":
            # the no-stop-the-world bound: even this family's WORST call
            # (settle tick / buffer fold / merge-down) beats the flat
            # baseline's routine tail
            assert mx < p99_flat, (
                f"{label}: max insert {mx:.0f}us >= flat p99 {p99_flat:.0f}us "
                "— a stop-the-world restructure leaked into the insert path"
            )
            rows.append(
                Row(
                    f"p99ratio_{label}_insert",
                    p99 / p99_flat,
                    f"p99={p99:.0f}us;flat_p99={p99_flat:.0f}us",
                )
            )
    return rows
