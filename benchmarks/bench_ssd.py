"""Paper Table 1(b) + Figs 7/8: on-SSD AMQ comparison, small (1:4) and
large (1:24) RAM-to-filter ratios.

The SSD does not exist in this container; every structure logs its
exact page-access schedule and the paper's measured X25-M constants
(cost_model.PAPER_SSD) convert the schedule to modeled ops/s — the same
bottom line the paper measures.  Structures are scaled down ~2^13 from
the paper's 2GB RAM (ratios, not absolutes, are the reproducible
quantity); the derived column carries the paper-comparable ratios:
CF/BQF insert speedup over the best BF variant (paper: 8.6-11x), the
CF-vs-BQF crossover at 1:24 (paper: CF 26% faster), and BQF lookup
dominance (paper: >=1.6x).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import filters
from repro.core import bloom, quotient_filter as qf
from repro.core.bf_variants import (
    BufferedBloomFilter,
    ElevatorBloomFilter,
    ForestBloomFilter,
)
from repro.core.cost_model import PAPER_SSD, modeled_throughput

from .common import Row, keys_u32

RAM_Q = 11  # in-"RAM" QF buckets (paper: 2 GB)
P_BITS = 26  # fingerprint bits -> fp ~ 1/4096 at these loads
FP = 1 / 4096


class _Functional:
    """Host adapter giving the functional ``(cfg, state)`` filters the
    same insert/lookup/io surface as the BF-variant dataclasses.

    Both step functions are jitted with donated state — the unified
    API's design property: flush/merge triggers and I/O accounting are
    device arithmetic, so the whole ingest runs as compiled programs."""

    def __init__(self, name: str, **spec):
        self.cfg, self.state = filters.make(name, **spec)
        self._insert = jax.jit(
            lambda s, ks: filters.insert(self.cfg, s, ks), donate_argnums=0
        )
        self._probe = jax.jit(
            lambda s, ks: filters.probe(self.cfg, s, ks), donate_argnums=0
        )

    def insert(self, keys) -> None:
        self.state = self._insert(self.state, keys)

    def lookup(self, keys):
        self.state, hit = self._probe(self.state, keys)
        return hit

    @property
    def io(self):
        return filters.to_iolog(self.state.io)


def _mk_structs(ratio: int, n_total: int):
    disk_q = RAM_Q + max(2, int(np.ceil(np.log2(ratio * 1.8))))
    bqf = _Functional("buffered_qf", ram_q=RAM_Q, disk_q=disk_q, p=P_BITS)
    cf = _Functional("cascade", ram_q=RAM_Q, p=P_BITS, fanout=2, levels=6)
    k = 12
    m_bits = int(n_total * k / np.log(2))
    ram_bits = m_bits // ratio
    # the RAM buffer holds pending bit-WRITE entries (~8 B each), not bits
    ebf = ElevatorBloomFilter(
        bloom.BloomConfig(m_bits=m_bits, k=k), buffer_capacity_bits=ram_bits // 64
    )
    bbf = BufferedBloomFilter(
        bloom.BloomConfig(m_bits=m_bits, k=k),
        ram_bytes=ram_bits // 8,
        block_bytes=4096 * 8,
        page_bytes=512,
    )
    fbf = ForestBloomFilter(
        bits_per_element=k / np.log(2),
        ram_bytes=ram_bits // 8,
        total_elements=n_total,
    )
    return {"cf": cf, "bqf": bqf, "ebf": ebf, "bbf": bbf, "fbf": fbf}


def _experiment(ratio: int, tag: str) -> list[Row]:
    rng = np.random.default_rng(ratio)
    cap_ram = qf.QFConfig(q=RAM_Q, r=1).capacity
    n_total = int(ratio * cap_ram)
    structs = _mk_structs(ratio, n_total)
    all_keys = keys_u32(rng, n_total)

    rows = []
    ins_tput = {}
    for name, s in structs.items():
        step = max(256, n_total // 64)
        for i in range(0, n_total, step):
            s.insert(all_keys[i : i + step])
        ins_tput[name] = modeled_throughput(n_total, s.io, PAPER_SSD)

    # lookups: fresh io accounting
    probes_uni = keys_u32(rng, 2048, lo=2**31)
    probes_hit = all_keys[rng.integers(0, n_total, 2048)]
    uni_tput, hit_tput = {}, {}
    for name, s in structs.items():
        before = s.io.snapshot()
        r_uni = s.lookup(probes_uni)
        mid = s.io.snapshot()
        r_hit = s.lookup(probes_hit)
        assert bool(jnp.asarray(r_hit).all()), f"{name}: false negative!"
        uni_tput[name] = modeled_throughput(2048, mid.delta(before), PAPER_SSD)
        hit_tput[name] = modeled_throughput(2048, s.io.snapshot().delta(mid), PAPER_SSD)

    best_bf_ins = max(ins_tput[n] for n in ("ebf", "bbf", "fbf"))
    for name in structs:
        rows.append(
            Row(
                f"ssd_{tag}_insert_{name}",
                1e6 / max(ins_tput[name], 1e-9),
                f"modeled_ops/s={ins_tput[name]:.0f}"
                + (
                    f";vs_best_bf={ins_tput[name] / best_bf_ins:.1f}x"
                    if name in ("cf", "bqf")
                    else ""
                ),
            )
        )
        rows.append(
            Row(
                f"ssd_{tag}_lookup_uniform_{name}",
                1e6 / max(uni_tput[name], 1e-9),
                f"modeled_ops/s={uni_tput[name]:.0f}",
            )
        )
        rows.append(
            Row(
                f"ssd_{tag}_lookup_success_{name}",
                1e6 / max(hit_tput[name], 1e-9),
                f"modeled_ops/s={hit_tput[name]:.0f}",
            )
        )
    rows.append(
        Row(
            f"ssd_{tag}_cf_vs_bqf_insert",
            0.0,
            f"cf/bqf={ins_tput['cf'] / ins_tput['bqf']:.2f} (paper large: 1.26)",
        )
    )
    return rows


def run() -> list[Row]:
    return _experiment(4, "small") + _experiment(24, "large")
