"""Paper Table 1(a): in-RAM QF vs BF at three false-positive rates.

Measures jitted CPU throughput for uniform-random inserts, uniform
random lookups, and successful lookups at the paper's operating point
(structures 75% full).  Derived column reports QF/BF speedup to compare
against the paper's 1.3-2.5x insert / 0.6-0.7x lookup findings.
(Container scale: filters sized at 2^18 buckets instead of the paper's
2^31; the *ratios* are the reproducible quantity on different hardware.)
"""

from __future__ import annotations

import numpy as np
import jax

from repro import filters

from .common import Row, keys_u32, time_fn


# fp rates from the paper: 1/64, 1/512, 1/4096 -> r = 6, 9, 12
CASES = [(1 / 64, 6), (1 / 512, 9), (1 / 4096, 12)]
Q = 18
LOAD = 0.75
LOOKUP_BATCH = 1 << 16
INSERT_BATCH = 1 << 14


def run() -> list[Row]:
    rows = []
    rng = np.random.default_rng(0)
    n = int((1 << Q) * LOAD)
    for fp, r in CASES:
        cfg, st = filters.make("qf", q=Q, r=r, slack=2048)
        keys = keys_u32(rng, n)
        st = filters.insert(cfg, st, keys)

        # BF at the same fp rate: optimal k, m = n*k/ln2
        k = max(1, round(-np.log2(fp)))
        m_bits = int(n * k / np.log(2))
        bcfg, bits = filters.make("bloom", m_bits=m_bits, k=k)
        bits = filters.insert(bcfg, bits, keys)

        # jit the timed step functions: measure the fused programs, not
        # eager per-op dispatch (cfg is static via closure)
        qf_ins = jax.jit(lambda s, ks: filters.insert(cfg, s, ks))
        bf_ins = jax.jit(lambda s, ks: filters.insert(bcfg, s, ks))
        qf_has = jax.jit(lambda s, ks: filters.contains(cfg, s, ks))
        bf_has = jax.jit(lambda s, ks: filters.contains(bcfg, s, ks))

        batch = keys_u32(rng, INSERT_BATCH)
        t_qf_ins = time_fn(lambda: qf_ins(st, batch)) / INSERT_BATCH
        t_bf_ins = time_fn(lambda: bf_ins(bits, batch)) / INSERT_BATCH

        probes = keys_u32(rng, LOOKUP_BATCH, lo=2**31)
        t_qf_uni = time_fn(lambda: qf_has(st, probes)) / LOOKUP_BATCH
        t_bf_uni = time_fn(lambda: bf_has(bits, probes)) / LOOKUP_BATCH

        hits = keys[:LOOKUP_BATCH]
        t_qf_succ = time_fn(lambda: qf_has(st, hits)) / len(hits)
        t_bf_succ = time_fn(lambda: bf_has(bits, hits)) / len(hits)

        tag = f"fp{fp:.0e}"
        rows += [
            Row(f"inram_insert_qf_{tag}", t_qf_ins * 1e6,
                f"qf/bf_speedup={t_bf_ins / t_qf_ins:.2f}"),
            Row(f"inram_insert_bf_{tag}", t_bf_ins * 1e6,
                f"ops/s={1 / t_bf_ins:.0f}"),
            Row(f"inram_lookup_uniform_qf_{tag}", t_qf_uni * 1e6,
                f"qf/bf_speedup={t_bf_uni / t_qf_uni:.2f}"),
            Row(f"inram_lookup_uniform_bf_{tag}", t_bf_uni * 1e6,
                f"ops/s={1 / t_bf_uni:.0f}"),
            Row(f"inram_lookup_success_qf_{tag}", t_qf_succ * 1e6,
                f"qf/bf_speedup={t_bf_succ / t_qf_succ:.2f}"),
            Row(f"inram_lookup_success_bf_{tag}", t_bf_succ * 1e6,
                f"ops/s={1 / t_bf_succ:.0f}"),
        ]
    return rows
