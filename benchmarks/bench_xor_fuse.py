"""Frozen (binary-fuse) tier: construction cost, probe latency, and the
space win over QF cold levels at the same fp-rate target.

The paper's cascade keeps every cold level a QF so merges stay
streaming; the frozen tier trades that mutability for ~20-30% fewer
bits per key at a fixed 3-read probe.  These rows quantify both sides
of the trade: ``xf_freeze_*`` is the write-path cost (a full re-peel),
``xf_probe_*`` the read path vs an equally-loaded QF, and the
``derived`` column carries the bits/key comparison the cost model
predicts (validated in ``tests/test_xor_fuse.py``).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro import filters
from repro.core import cost_model
from repro.core import fuse_filter as fuse
from repro.core import quotient_filter as qf

from .common import Row, keys_u32, time_fn


def run() -> list[Row]:
    rows = []
    rng = np.random.default_rng(17)

    # -- construction: host peel + device assignment, us per key --------
    for n in (10_000, 100_000):
        keys = keys_u32(rng, n)
        cfg = fuse.make_config(n, p=26)
        t = time_fn(lambda: fuse.freeze_keys(cfg, keys), warmup=1, iters=3)
        rows.append(
            Row(
                f"xf_freeze_n{n}",
                t / n * 1e6,
                f"us_per_key;slots={cfg.slots};bits_per_key="
                f"{cost_model.fuse_bits_per_key(n, cfg.fp_bits):.2f}",
            )
        )

    # -- probe: frozen 3-gather vs a QF cold level, same key set --------
    n = 100_000
    keys = keys_u32(rng, n)
    probes = keys_u32(rng, 1 << 14)
    r = 13
    fcfg = fuse.make_config(n, p=30, fp_bits=cost_model.fuse_fp_bits_for(r))
    fst = fuse.freeze_keys(fcfg, keys)
    qcfg = qf.QFConfig(q=17, r=r, slack=4096)
    fq, fr_ = qf.fingerprints(qcfg, keys)
    sq, sr = qf._pad_sort(fq, fr_, jnp.ones(fq.shape, bool))
    qst = qf.build_sorted(qcfg, sq, sr, n)
    pq, pr = qf.fingerprints(qcfg, probes)

    t_f = time_fn(lambda: fuse.contains(fcfg, fst, probes))
    t_q = time_fn(lambda: qf.lookup(qcfg, qst, pq, pr))
    f_bpk = fcfg.slots * fcfg.fp_bits / n
    q_bpk = cost_model.qf_bits_per_key(qcfg.q, r, qcfg.slack, 0.75)
    rows.append(
        Row(
            "xf_probe_fuse",
            t_f * 1e6,
            f"queries=16384;reads_per_q={cost_model.FUSE_PROBE_READS};"
            f"bits_per_key={f_bpk:.2f}",
        )
    )
    rows.append(
        Row(
            "xf_probe_qf_cold",
            t_q * 1e6,
            f"queries=16384;reads_per_q={cost_model.QF_PROBE_READS};"
            f"bits_per_key={q_bpk:.2f}",
        )
    )
    rows.append(
        Row(
            "xf_space_saving",
            (1 - f_bpk / q_bpk) * 100,
            f"percent;fuse_bpk={f_bpk:.2f};qf_bpk={q_bpk:.2f}",
        )
    )

    # -- cascade demotion end-to-end: frozen vs all-QF cold tier --------
    spec = dict(ram_q=8, p=26, fanout=2, levels=4)
    ccfg_q, cst_q = filters.make("cascade", **spec)
    ccfg_f, cst_f = filters.make("cascade", frozen_below=1, **spec)
    batches = keys_u32(rng, 2048).reshape(16, 128)

    def ingest(cfg, st):
        for b in batches:
            st = filters.insert(cfg, st, b)
        return st

    t_iq = time_fn(lambda: ingest(ccfg_q, cst_q), warmup=1, iters=3)
    t_if = time_fn(lambda: ingest(ccfg_f, cst_f), warmup=1, iters=3)
    frozen_bytes = sum(
        ccfg_f.level_size_bytes(i) for i in range(ccfg_f.levels)
        if ccfg_f.is_frozen(i)
    )
    qf_bytes = sum(
        ccfg_q.level_cfg(i).size_bytes for i in range(ccfg_q.levels)
        if ccfg_f.is_frozen(i)
    )
    rows.append(
        Row(
            "xf_cascade_ingest_qf",
            t_iq / batches.size * 1e6,
            "us_per_key;all-QF levels (device lax.switch collapse)",
        )
    )
    rows.append(
        Row(
            "xf_cascade_ingest_frozen",
            t_if / batches.size * 1e6,
            f"us_per_key;frozen_below=1;cold_saving="
            f"{(1 - frozen_bytes / qf_bytes) * 100:.1f}%",
        )
    )
    return rows
