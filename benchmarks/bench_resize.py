"""Paper §3 "Resizing": what dynamic growth actually costs.

Three questions, three sections:

* ``resize_double_*`` — one in-place doubling (requotient + rebuild,
  the paper's borrow-a-bit resize) vs ``resize_rebuild_*``, the naive
  alternative of building a fresh filter at the doubled size and
  re-inserting every key.  The doubling is one streaming pass over the
  table and never touches the original keys; the rebuild needs the key
  set (which an AMQ normally no longer has) and re-hashes all of it.
  Both backends: the doubling's rebuild pass routes through the Pallas
  ``qf_build_planes`` kernel under ``backend="pallas"``.
* ``resize_schedule_step*`` — growth-schedule sweep: ingest 8x the
  initial capacity through ``filters.auto_grow`` where each structural
  step adds 1, 2, or 3 quotient bits (2x / 4x / 8x capacity).  Fewer,
  bigger steps re-stream the table fewer times; the derived column
  carries the total structural steps and the modeled bytes streamed.
* ``resize_grow_{buffered_qf,cascade}`` — one growth step of the
  layered structures: buffered re-streams its disk QF; the cascade
  deepens for free (the new level starts empty).
"""

from __future__ import annotations

import numpy as np
import jax

from repro import filters
from repro.core import quotient_filter as qf

from .common import Row, keys_u32, time_fn

Q0 = 12  # starting quotient bits for the flat-QF sections
P = 28  # fingerprint bits


def _filled_qf(rng, q: int, backend: str):
    cfg, st = filters.make("qf", q=q, r=P - q, backend=backend)
    keys = keys_u32(rng, cfg.core.capacity)
    st = filters.insert(cfg, st, keys)
    return cfg, jax.block_until_ready(st), keys


def _doubling_vs_rebuild(rng) -> list[Row]:
    rows = []
    for backend in ("reference", "pallas"):
        cfg, st, keys = _filled_qf(rng, Q0, backend)

        def double():
            _, out = filters.resize(cfg, st, new_q=cfg.q + 1)
            return out

        # min-of-7: the pallas-vs-reference comparison on these rows is
        # gated, and on CPU both backends lower to near-identical XLA —
        # a scheduler stall in a median-of-5 reads as a fake 1.3x gap
        t_double = time_fn(double, iters=7, agg=np.min)

        big_cfg, _ = filters.make("qf", q=Q0 + 1, r=P - Q0 - 1, backend=backend)

        def rebuild():
            _, empty = filters.make("qf", q=Q0 + 1, r=P - Q0 - 1, backend=backend)
            return filters.insert(big_cfg, empty, keys)

        t_rebuild = time_fn(rebuild, iters=7, agg=np.min)
        tag = f"q{Q0}_{backend}"
        rows.append(
            Row(
                f"resize_double_{tag}",
                t_double * 1e6,
                f"streamed_bytes={2 * cfg.core.size_bytes}",
            )
        )
        rows.append(
            Row(
                f"resize_rebuild_{tag}",
                t_rebuild * 1e6,
                f"double/rebuild={t_double / t_rebuild:.2f}x",
            )
        )
    return rows


def _growth_schedules(rng) -> list[Row]:
    """Ingest 8x the initial capacity with different per-step growth."""
    rows = []
    n_total = 8 * qf.QFConfig(q=Q0, r=P - Q0).capacity
    all_keys = keys_u32(rng, n_total)
    chunk = 512
    for step_bits in (1, 2, 3):
        cfg, st = filters.make("qf", q=Q0, r=P - Q0)
        steps, streamed = 0, 0.0
        t0 = __import__("time").perf_counter()
        for i in range(0, n_total, chunk):
            st = filters.insert(cfg, st, all_keys[i : i + chunk])
            if bool(filters.needs_resize(cfg, st)):
                streamed += cfg.core.size_bytes  # stream old table in
                cfg, st = filters.resize(cfg, st, new_q=cfg.q + step_bits)
                streamed += cfg.core.size_bytes  # new table out
                steps += 1
        jax.block_until_ready(st)
        elapsed = __import__("time").perf_counter() - t0
        assert not bool(filters.stats(cfg, st)["overflow"])
        rows.append(
            Row(
                f"resize_schedule_step{step_bits}",
                elapsed / n_total * 1e6,
                f"final_q={cfg.q};grow_steps={steps};streamed_bytes={streamed:.0f}",
            )
        )
    return rows


def _layered_growth(rng) -> list[Row]:
    rows = []
    cfg, st = filters.make("buffered_qf", ram_q=8, disk_q=Q0, p=P)
    keys = keys_u32(rng, cfg.disk.capacity)
    for i in range(0, keys.shape[0], 128):
        st = filters.insert(cfg, st, keys[i : i + 128])
    jax.block_until_ready(st)
    t = time_fn(lambda: filters.grow(cfg, st)[1])
    rows.append(
        Row(
            "resize_grow_buffered_qf",
            t * 1e6,
            f"disk_q={cfg.disk_q}->{cfg.disk_q + 1}",
        )
    )

    ccfg, cst = filters.make("cascade", ram_q=8, p=P, fanout=2, levels=3)
    ckeys = keys_u32(rng, 2048)
    for i in range(0, 2048, 128):
        cst = filters.insert(ccfg, cst, ckeys[i : i + 128])
    jax.block_until_ready(cst)
    t = time_fn(lambda: filters.grow(ccfg, cst)[1])
    rows.append(
        Row("resize_grow_cascade", t * 1e6, f"levels={ccfg.levels}->{ccfg.levels + 1}")
    )
    return rows


def run() -> list[Row]:
    rng = np.random.default_rng(42)
    return _doubling_vs_rebuild(rng) + _growth_schedules(rng) + _layered_growth(rng)
