"""Paper Fig 9 / §5.3: cascade-filter insert/lookup tradeoff vs fanout.

Higher fanout -> fewer levels -> faster lookups, slower inserts (each
level rewritten up to b times).  Modeled on the paper's SSD constants.
"""

from __future__ import annotations

import numpy as np

from repro.core.cascade_filter import CascadeFilter
from repro.core.cost_model import PAPER_SSD, modeled_throughput

from .common import Row, keys_u32

RAM_Q = 10
P_BITS = 26
N = 40_000


def run() -> list[Row]:
    rows = []
    results = {}
    for fanout in (2, 4, 16):
        rng = np.random.default_rng(9)
        cf = CascadeFilter(ram_q=RAM_Q, p=P_BITS, fanout=fanout)
        keys = keys_u32(rng, N)
        step = 512
        for i in range(0, N, step):
            cf.insert(keys[i : i + step])
        ins = modeled_throughput(N, cf.io, PAPER_SSD)
        before = cf.io.snapshot()
        cf.lookup(keys_u32(rng, 2048, lo=2**31))
        look = modeled_throughput(2048, cf.io.delta(before), PAPER_SSD)
        results[fanout] = (ins, look, cf.n_nonempty_levels())
        rows.append(
            Row(
                f"fanout_{fanout}",
                1e6 / max(ins, 1e-9),
                f"insert_ops/s={ins:.0f};lookup_ops/s={look:.0f};"
                f"levels={cf.n_nonempty_levels()}",
            )
        )
    # paper's qualitative claim: lookup(16) >= lookup(2), insert(2) >= insert(16)
    ok = results[16][1] >= results[2][1] and results[2][0] >= results[16][0]
    rows.append(Row("fanout_tradeoff_holds", 0.0, f"ok={ok}"))
    return rows
