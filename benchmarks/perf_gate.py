"""CI perf-regression gate: compare a bench run against the committed baseline.

Usage::

    python -m benchmarks.run --only bench_resize,bench_incremental
    python -m benchmarks.perf_gate            # compare + exit 1 on regression
    python -m benchmarks.perf_gate --update   # refresh the committed baseline

The committed baseline (``experiments/bench_baseline.json``) stores
``us_per_call`` per benchmark row.  Absolute timings are machine-bound,
so the gate is *relative*: it computes each shared row's
current/baseline ratio, takes the median ratio as the machine-speed
normalizer (a uniformly slower runner shifts every ratio equally), and
fails only when a row regresses more than ``--threshold`` (default
1.5x) beyond that normalizer — i.e. when one benchmark got slower
*relative to the others*, which is what a code regression looks like.

Rows present on only one side are reported but never fail the gate
(new benchmarks land before their baseline; retired ones linger until
the next ``--update``).  Commits whose message contains ``[perf-skip]``
bypass the job entirely (wired in ``.github/workflows/ci.yml``).
"""

from __future__ import annotations

import argparse
import csv
import json
import os
import statistics
import sys

BASELINE_PATH = os.path.join("experiments", "bench_baseline.json")
RESULTS_PATH = os.path.join("experiments", "bench_results.csv")

# rows the gate watches; keep in sync with the perf-gate CI job's --only
GATED_PREFIXES = ("resize_", "incr_", "kernelratio_", "p99ratio_")

# rows whose value is already a *ratio* of two timings from the same
# run: machine speed cancels in the quotient, so these compare to
# baseline directly — no median normalizer, and they are excluded from
# computing it
RATIO_PREFIXES = ("kernelratio_", "p99ratio_")

# absolute ceilings for ratio rows, applied to every matching row of
# the current run — including rows too new to have a baseline entry:
#
# * ``kernelratio_*`` (pallas/reference): the deployed kernel path may
#   never be more than 10% slower than the reference path it replaces
#   (PR 7's "strictly faster" pledge).
# * ``p99ratio_*_insert`` (family p99 / flat in-place p99, from
#   ``bench_steady_state``): the steady-state tail pledge.  The steady
#   ceiling 0.20 is this family's acceptance bar — p99 at least 5x
#   below the in-place path; the rest sit ~2-3x above their measured
#   values (0.05-0.10) so scheduler noise cannot flake the job while a
#   real stop-the-world regression still trips it.  Unlisted p99ratio
#   rows get the catch-all: any buffered family's tail must stay below
#   half the in-place baseline.
RATIO_CEILINGS = {
    "kernelratio_": 1.10,
    "p99ratio_steady_insert": 0.20,
    "p99ratio_buffered_insert": 0.15,
    "p99ratio_cascade_insert": 0.25,
    "p99ratio_cascade_frozen_insert": 0.15,
    "p99ratio_": 0.50,
}


def ratio_ceiling(name: str) -> float | None:
    """Absolute ceiling for a ratio row: exact name first, then the
    longest matching prefix; None for rows gated only vs baseline."""
    if name in RATIO_CEILINGS:
        return RATIO_CEILINGS[name]
    best = None
    for prefix, ceiling in RATIO_CEILINGS.items():
        if name.startswith(prefix) and (best is None or len(prefix) > best[0]):
            best = (len(prefix), ceiling)
    return best[1] if best else None


def read_results(path: str) -> dict[str, float]:
    rows: dict[str, float] = {}
    with open(path) as f:
        for rec in csv.DictReader(f):
            name = rec["name"]
            if name.startswith(GATED_PREFIXES):
                rows[name] = float(rec["us_per_call"])
    return rows


def read_baseline(path: str) -> dict[str, float]:
    with open(path) as f:
        return {k: float(v) for k, v in json.load(f)["rows"].items()}


def update_baseline(results: dict[str, float]) -> None:
    payload = {
        "comment": (
            "CI perf-gate baseline (us_per_call). Refresh with "
            "`python -m benchmarks.perf_gate --update` after an accepted "
            "perf change; bypass one commit with [perf-skip]."
        ),
        "rows": {k: round(v, 3) for k, v in sorted(results.items())},
    }
    with open(BASELINE_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"baseline refreshed: {len(results)} rows -> {BASELINE_PATH}")


def compare(
    current: dict[str, float], baseline: dict[str, float], threshold: float
) -> int:
    shared = sorted(set(current) & set(baseline))
    if not shared:
        print("perf-gate: no shared rows between results and baseline", file=sys.stderr)
        return 1
    ratios = {k: current[k] / baseline[k] for k in shared}
    timed = [k for k in shared if not k.startswith(RATIO_PREFIXES)]
    machine = statistics.median(ratios[k] for k in timed) if timed else 1.0
    print(f"machine-speed normalizer (median ratio): {machine:.3f}")
    print(f"{'row':40s} {'base_us':>12s} {'now_us':>12s} {'rel':>8s}")
    failed = []
    for k in shared:
        # ratio rows are machine-invariant: gate them un-normalized
        rel = ratios[k] if k.startswith(RATIO_PREFIXES) else ratios[k] / machine
        flag = ""
        if rel > threshold:
            failed.append(k)
            flag = f"  REGRESSION (> {threshold:.2f}x)"
        elif rel < 1 / threshold:
            flag = "  (improved — consider --update)"
        print(f"{k:40s} {baseline[k]:12.1f} {current[k]:12.1f} {rel:7.2f}x{flag}")
    for k in sorted(set(current) - set(baseline)):
        print(f"{k:40s} {'--':>12s} {current[k]:12.1f}      new (not gated)")
    for k in sorted(set(baseline) - set(current)):
        print(f"{k:40s} {baseline[k]:12.1f} {'--':>12s}      missing from run")
    # absolute ratio ceilings: every ratio row of the RUN (baselined or
    # not) must stay at or under its ceiling
    for k in sorted(current):
        ceiling = ratio_ceiling(k) if k.startswith(RATIO_PREFIXES) else None
        if ceiling is not None and current[k] > ceiling:
            if k not in failed:
                failed.append(k)
            print(
                f"{k:40s} ratio {current[k]:.3f} exceeds the absolute "
                f"ceiling {ceiling:.2f}  REGRESSION",
                file=sys.stderr,
            )
    if failed:
        print(
            f"\nperf-gate FAILED: {len(failed)} row(s) regressed beyond "
            f"{threshold:.2f}x relative to the machine normalizer: "
            + ", ".join(failed),
            file=sys.stderr,
        )
        return 1
    print("\nperf-gate passed")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=RESULTS_PATH)
    ap.add_argument("--baseline", default=BASELINE_PATH)
    ap.add_argument("--threshold", type=float, default=1.5)
    ap.add_argument(
        "--update", action="store_true", help="rewrite the baseline from --results"
    )
    args = ap.parse_args()

    current = read_results(args.results)
    if args.update:
        update_baseline(current)
        return
    baseline = read_baseline(args.baseline)
    sys.exit(compare(current, baseline, args.threshold))


if __name__ == "__main__":
    main()
