"""Analyzer wall-time: what the CI `analysis` job costs per run.

One row per analyzer (``analysis_lint`` / ``analysis_spec`` /
``analysis_trace``) so the CSV history shows when an analyzer's cost
drifts — e.g. a new rule making the lint quadratic, or a new registry
family doubling the trace audit.  These rows are informational
(``analysis_`` is not a gated prefix in ``benchmarks.perf_gate``):
wall-time here tracks repo size by design.

The timed unit is one full in-process run against the committed
artifacts, including jaxpr tracing for the audit; a single iteration
each (these are multi-second passes, not microbenchmarks).
"""

from __future__ import annotations

import os
import time

from .common import Row

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run() -> list[Row]:
    from repro.analysis.lint import load_config, run_lint
    from repro.analysis.spec_check import run_spec_check
    from repro.analysis.trace_audit import run_audit

    rows = []

    cfg = load_config(ROOT)
    t = _timed(lambda: run_lint(ROOT, cfg))
    res = run_lint(ROOT, cfg)
    rows.append(
        Row(
            "analysis_lint",
            t * 1e6,
            f"files={res.n_files};scopes={res.n_scopes};ok={int(res.ok)}",
        )
    )

    t = _timed(lambda: run_spec_check())
    rows.append(Row("analysis_spec", t * 1e6, "kernels=6"))

    t = _timed(lambda: run_audit())
    rows.append(Row("analysis_trace", t * 1e6, "families=10"))
    return rows
