"""Shared benchmark utilities: timing + CSV rows."""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


def time_fn(fn, *args, warmup: int = 2, iters: int = 5, agg=np.median, **kw) -> float:
    """Aggregated wall seconds per call (blocks on jax results).

    ``agg=np.min`` de-noises runs whose value feeds a *gated* row:
    min-of-N factors out this container's scheduler stalls (cf. the
    min-of-4 replays in ``bench_incremental``), where a stall landing
    in the median would shift a ratio by ~1.5x and flake the gate."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(agg(ts))


def time_pair(fa, fb, *, warmup: int = 2, iters: int = 30) -> tuple[float, float]:
    """Paired minima for a gated *ratio* row: (min seconds fa, min seconds fb).

    The two closures are timed interleaved in ONE loop, so both minima
    sample the same machine-condition window.  Timing them in separate
    ``time_fn`` passes lets a frequency/scheduler shift between the
    passes move the quotient by ~20% even when the computations are
    identical — enough to flake an absolute-ceiling gate."""
    for _ in range(warmup):
        jax.block_until_ready(fa())
        jax.block_until_ready(fb())
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fa())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fb())
        tb.append(time.perf_counter() - t0)
    return float(np.min(ta)), float(np.min(tb))


def keys_u32(rng, n, lo=0, hi=2**32):
    import jax.numpy as jnp

    return jnp.asarray(rng.integers(lo, hi, size=n, dtype=np.int64).astype(np.uint32))
