"""Render EXPERIMENTS.md tables from dry-run JSONs.

Usage: python experiments/make_tables.py [dir] > table.md
"""

import json
import os
import sys


def load(d):
    out = {}
    for f in sorted(os.listdir(d)):
        if not f.endswith(".json"):
            continue
        j = json.load(open(os.path.join(d, f)))
        out[(j["arch"], j["shape"], j.get("mesh", "16x16"))] = j
    return out


def fmt_cell(j):
    if j["status"] == "skipped":
        return None
    if j["status"] == "error":
        return {"status": "ERROR"}
    r = j["roofline"]
    m = j["memory"]
    return {
        "hbm": m["hbm_bytes_per_device"] / 2**30,
        "fits": bool(m["fits_16GiB"]),
        "tc": r["t_compute_s"],
        "tm": r["t_memory_s"],
        "tx": r["t_collective_s"],
        "bound": r["bound"],
        "uff": r["useful_flop_fraction"],
        "mfu": r["roofline_mfu"],
        "compile": j["compile_s"],
    }


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    data = load(d)
    meshes = sorted({k[2] for k in data})
    for mesh in meshes:
        print(f"\n### Mesh {mesh}\n")
        print(
            "| arch | shape | hbm/dev GiB | fits | t_compute s | t_memory s "
            "| t_coll s | bound | useful-flop frac | roofline MFU |"
        )
        print("|---|---|---|---|---|---|---|---|---|---|")
        for (arch, shape, m), j in sorted(data.items()):
            if m != mesh:
                continue
            c = fmt_cell(j)
            if c is None:
                print(
                    f"| {arch} | {shape} | — | — | — | — | — "
                    "| skipped (full-attention; see DESIGN.md §5) | — | — |"
                )
                continue
            if c.get("status") == "ERROR":
                print(f"| {arch} | {shape} | ERROR | | | | | | | |")
                continue
            print(
                f"| {arch} | {shape} | {c['hbm']:.2f} | {'Y' if c['fits'] else 'N'} "
                f"| {c['tc']:.4f} | {c['tm']:.4f} | {c['tx']:.4f} | {c['bound']} "
                f"| {c['uff']:.2f} | {c['mfu']:.3f} |"
            )


if __name__ == "__main__":
    main()
