"""End-to-end driver: train a ~130M-param model for a few hundred steps
on the dedup'd synthetic stream, with checkpointing.

    PYTHONPATH=src python examples/train_e2e.py [--steps 200]

(Thin wrapper over repro.launch.train — the production entry point.)
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    args = sys.argv[1:]
    if not any(a.startswith("--steps") for a in args):
        args += ["--steps", "200"]
    raise SystemExit(
        main(["--arch", "mamba2-130m", "--batch", "8", "--seq", "512",
              "--ckpt-dir", "/tmp/repro_e2e_ckpt", "--ckpt-every", "50"] + args)
    )
