"""Streaming dataset dedup with a cascade filter (the paper's Webtable
workload), feeding a real training batch stream.

    PYTHONPATH=src python examples/dedup_pipeline.py
"""

from repro import filters
from repro.data.pipeline import DedupPipeline, PipelineConfig


def main():
    pipe = DedupPipeline(
        PipelineConfig(
            seq_len=512, batch_size=4, duplicate_fraction=0.35,
            dedup_ram_q=12, dedup_p=30, dedup_fanout=4, dedup_levels=4,
        )
    )
    for i, batch in enumerate(pipe.batches(10, docs_per_step=512)):
        s = pipe.state
        print(
            f"batch {i}: tokens {tuple(batch['tokens'].shape)} | "
            f"corpus seen={s.docs_seen} "
            f"kept={s.docs_kept} dropped(dup)={s.docs_dropped} "
            f"({100 * s.docs_dropped / max(s.docs_seen, 1):.1f}% dup rate)"
        )
    fs = filters.stats(pipe.filter_cfg, pipe.filter_state)
    print(
        f"cascade filter: {int(fs['n']):,} digests across "
        f"{int(fs['nonempty_levels'])} levels, {int(fs['merges'])} merges, "
        f"{fs['size_bytes']/1024:.0f} KiB modeled"
    )


if __name__ == "__main__":
    main()
