"""Quickstart: the paper's data structures in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import quotient_filter as qf
from repro.core.buffered_qf import BufferedQuotientFilter
from repro.core.cascade_filter import CascadeFilter
from repro.core.cost_model import PAPER_SSD, modeled_throughput


def main():
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.integers(0, 2**32, 50_000, dtype=np.int64).astype(np.uint32))

    # 1. Quotient filter (paper §3): insert / query / delete / resize
    cfg = qf.QFConfig(q=16, r=12)  # 64k buckets, fp ~ alpha * 2^-12
    st = qf.insert(cfg, qf.empty(cfg), keys[:40_000])
    print("QF load:", float(qf.load(cfg, st)))
    print("all present:", bool(qf.contains(cfg, st, keys[:40_000]).all()))
    absent = jnp.asarray(rng.integers(0, 2**32, 100_000, dtype=np.int64).astype(np.uint32))
    print("fp rate:", float(qf.contains(cfg, st, absent).mean()), "~", 0.61 * 2**-12)
    st = qf.delete(cfg, st, keys[:10_000])
    print("after delete:", int(st.n))
    big_cfg, big_st = qf.resize(cfg, st, 17)  # double it, no rehash
    print("resized still present:", bool(qf.contains(big_cfg, big_st, keys[10_000:40_000]).all()))

    # 2. Buffered QF (paper §4): RAM buffer + sequential flush to "flash"
    bqf = BufferedQuotientFilter(qf.QFConfig(q=12, r=16), qf.QFConfig(q=16, r=12))
    for i in range(0, 50_000, 2_000):
        bqf.insert(keys[i : i + 2_000])
    print("BQF insert modeled ops/s on the paper's SSD:",
          f"{modeled_throughput(50_000, bqf.io, PAPER_SSD):,.0f}")

    # 3. Cascade filter (paper §4): LSM-of-QFs, insert-optimized
    cf = CascadeFilter(ram_q=12, p=28, fanout=2)
    for i in range(0, 50_000, 2_000):
        cf.insert(keys[i : i + 2_000])
    print("CF levels:", cf.n_nonempty_levels(),
          "merges:", cf.io.merges,
          "insert modeled ops/s:", f"{modeled_throughput(50_000, cf.io, PAPER_SSD):,.0f}")
    print("CF membership:", bool(cf.lookup(keys[:5_000]).all()))


if __name__ == "__main__":
    main()
