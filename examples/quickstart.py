"""Quickstart: the paper's data structures through the one functional API.

Every filter is an opaque ``(cfg, state)`` pair from ``repro.filters``;
insert / contains / delete / merge are the same four verbs for every
structure, and ingest loops compile into a single ``jax.lax.scan``.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro import filters
from repro.core.cost_model import PAPER_SSD, modeled_throughput


def main():
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.integers(0, 2**32, 50_000, dtype=np.int64).astype(np.uint32))

    # 1. Quotient filter (paper §3): insert / query / delete
    cfg, st = filters.make("qf", q=16, r=12)  # 64k buckets, fp ~ alpha * 2^-12
    st = filters.insert(cfg, st, keys[:40_000])
    print("QF load:", float(filters.stats(cfg, st)["load"]))
    print("all present:", bool(filters.contains(cfg, st, keys[:40_000]).all()))
    absent = jnp.asarray(
        rng.integers(0, 2**32, 100_000, dtype=np.int64).astype(np.uint32)
    )
    print(
        "fp rate:", float(filters.contains(cfg, st, absent).mean()), "~", 0.61 * 2**-12
    )
    st = filters.delete(cfg, st, keys[:10_000])
    print("after delete:", int(filters.stats(cfg, st)["n"]))

    # 2. Buffered QF (paper §4): RAM buffer + sequential flush to "flash".
    #    The whole ingest loop is ONE jitted lax.scan — flush decisions are
    #    lax.cond on device counts, I/O accounting lives in device counters.
    bcfg, bst = filters.make("buffered_qf", ram_q=12, disk_q=16, p=28)
    batches = keys.reshape(25, 2_000)

    @jax.jit
    def ingest(state, key_batches):
        step = lambda s, ks: (filters.insert(bcfg, s, ks), None)
        return jax.lax.scan(step, state, key_batches)[0]

    bst = ingest(bst, batches)
    io = filters.to_iolog(bst.io)
    print("BQF flushes:", io.flushes,
          "| insert modeled ops/s on the paper's SSD:",
          f"{modeled_throughput(50_000, io, PAPER_SSD):,.0f}")

    # 3. Cascade filter (paper §4): LSM-of-QFs, insert-optimized — same verbs.
    ccfg, cst = filters.make("cascade", ram_q=12, p=28, fanout=2, levels=4)

    @jax.jit
    def ingest_cf(state, key_batches):
        step = lambda s, ks: (filters.insert(ccfg, s, ks), None)
        return jax.lax.scan(step, state, key_batches)[0]

    cst = ingest_cf(cst, batches)
    s = filters.stats(ccfg, cst)
    print("CF levels:", int(s["nonempty_levels"]),
          "merges:", int(s["merges"]),
          "insert modeled ops/s:",
          f"{modeled_throughput(50_000, filters.to_iolog(cst.io), PAPER_SSD):,.0f}")
    print("CF membership:", bool(filters.contains(ccfg, cst, keys[:5_000]).all()))

    # 4. Same API, different engine: route QF build/probe through the
    #    Pallas kernels (interpret mode on CPU, Mosaic on TPU).
    kcfg, kst = filters.make("qf", q=14, r=12, backend="pallas")
    kst = filters.insert(kcfg, kst, keys[:10_000])
    print("pallas backend membership:",
          bool(filters.contains(kcfg, kst, keys[:10_000]).all()))

    # 5. Dynamic resizing (paper §3, the QF's headline edge over Blooms):
    #    start deliberately tiny and let auto_grow double the table in
    #    place whenever the load crosses the operating point.
    gcfg, gst = filters.make("qf", q=10, r=18)
    for i in range(0, 50_000, 1_000):
        gcfg, gst = filters.auto_grow(gcfg, gst, keys[i : i + 1_000])
    gs = filters.stats(gcfg, gst)
    print("auto_grow: q 10 ->", gcfg.q,
          "| n:", int(gs["n"]),
          "| load:", round(float(gs["load"]), 2),
          "| overflow:", bool(gs["overflow"]),
          "| all present:", bool(filters.contains(gcfg, gst, keys).all()))


if __name__ == "__main__":
    main()
