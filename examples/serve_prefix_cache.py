"""Serving with an AMQ prefix-cache front (paper's per-subtable filter
pattern): repeated prompts skip the remote KV-store probe.

    PYTHONPATH=src python examples/serve_prefix_cache.py
"""

import numpy as np

from repro.serve.prefix_cache import PrefixCacheFilter


def main():
    pc = PrefixCacheFilter(q=14, r=16)
    rng = np.random.default_rng(0)
    remote_probes_without = 0
    remote_probes_with = 0
    catalog = []
    for step in range(20):
        # 60% fresh prompts, 40% repeats
        bsz = 32
        prompts = rng.integers(0, 32000, (bsz, 64))
        n_rep = int(0.4 * bsz)
        if catalog:
            for j in range(n_rep):
                prompts[j] = catalog[rng.integers(0, len(catalog))]
        hits = pc.check_and_insert(prompts)
        catalog.extend(list(prompts[np.asarray(~hits)]))
        remote_probes_without += bsz  # naive: always probe remote store
        remote_probes_with += int(hits.sum())  # filtered: only on maybe-hit
    print(f"remote probes naive={remote_probes_without}  "
          f"with QF front={remote_probes_with}  "
          f"({100*(1 - remote_probes_with/remote_probes_without):.0f}% saved)")
    print(f"filter load={pc.load:.2f}")


if __name__ == "__main__":
    main()
