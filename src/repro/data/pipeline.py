"""Streaming data pipeline with cascade-filter deduplication.

This is the paper's application layer (§1 "Applications"): a
decoupled-insert/query workload where every incoming document's digest
is checked against — and inserted into — an AMQ before tokenization.
Duplicates (or probable duplicates, at the filter's FP rate) are
dropped.  The filter state checkpoints with the pipeline and its merge
operation makes checkpoint consolidation cheap.

Stages: synthetic corpus -> digest -> CF dedup -> tokenize (hash stub)
-> pack to fixed-length rows -> global batch.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator

import numpy as np
import jax
import jax.numpy as jnp

from repro import filters


@dataclass
class PipelineConfig:
    vocab_size: int = 32000
    seq_len: int = 1024
    batch_size: int = 8
    dedup_family: str = "cascade"  # any registry family ("cascade", "qf", ...)
    dedup_ram_q: int = 16  # Q0 buckets of the cascade filter (q for "qf")
    dedup_p: int = 30  # fingerprint bits (fp rate ~ n * 2^-p)
    dedup_fanout: int = 4
    dedup_levels: int = 3  # static disk-level depth of the cascade
    dedup_chunk: int = 1024  # incremental-migration / settle chunk (qf, steady_qf)
    # cascade cold-tier demotion: depth below which merged-down levels
    # freeze into binary-fuse form; "auto" asks the cost model
    # (``cost_model.recommend_frozen_below``), None keeps all-QF levels.
    # Frozen dedup filters cannot delete, which this pipeline never does.
    dedup_frozen_below: "int | str | None" = None
    duplicate_fraction: float = 0.3  # synthetic corpus duplication rate
    doc_len_range: tuple = (64, 512)
    seed: int = 0

    def dedup_spec(self) -> dict:
        if self.dedup_family == "cascade":
            spec = dict(
                ram_q=self.dedup_ram_q,
                p=self.dedup_p,
                fanout=self.dedup_fanout,
                levels=self.dedup_levels,
            )
            fb = self.dedup_frozen_below
            if fb == "auto":
                from repro.core import cost_model

                fb = cost_model.recommend_frozen_below(
                    self.dedup_ram_q,
                    self.dedup_p,
                    fanout=self.dedup_fanout,
                    levels=self.dedup_levels,
                )
            if fb is not None:
                spec["frozen_below"] = fb
            return spec
        if self.dedup_family == "qf":
            return dict(q=self.dedup_ram_q, r=self.dedup_p - self.dedup_ram_q)
        if self.dedup_family == "steady_qf":
            # LSM-style steady-state ingest: O(buffer) inserts, settle
            # ticks bounded by the chunk — bounded p99 per pipeline step
            return dict(
                q=self.dedup_ram_q,
                r=self.dedup_p - self.dedup_ram_q,
                chunk=self.dedup_chunk,
            )
        raise ValueError(f"no dedup spec mapping for {self.dedup_family!r}")


@dataclass
class PipelineState:
    docs_seen: int = 0
    docs_kept: int = 0
    docs_dropped: int = 0
    token_backlog: list = field(default_factory=list)


class SyntheticCorpus:
    """Deterministic document stream with injected duplicates —
    the Webtable-style crawl in miniature."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self._originals: list[int] = []

    def batch(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        """Returns (doc_ids uint32, is_dup bool) for n documents."""
        ids = np.empty(n, np.uint32)
        dup = np.zeros(n, bool)
        for i in range(n):
            if self._originals and self.rng.random() < self.cfg.duplicate_fraction:
                ids[i] = self.rng.choice(self._originals[-10_000:])
                dup[i] = True
            else:
                new = np.uint32(self.rng.integers(0, 2**32, dtype=np.uint64))
                ids[i] = new
                self._originals.append(int(new))
        return ids, dup

    def tokens_for(self, doc_id: int) -> np.ndarray:
        """Stub tokenizer: deterministic token stream from the digest."""
        r = np.random.default_rng(int(doc_id))
        n = r.integers(*self.cfg.doc_len_range)
        return r.integers(1, self.cfg.vocab_size, size=n, dtype=np.int32)


class DedupPipeline:
    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        self.corpus = SyntheticCorpus(cfg)
        self.filter_cfg, self.filter_state = filters.make(
            cfg.dedup_family, **cfg.dedup_spec()
        )
        self.state = PipelineState()

    def _dedup(self, doc_ids: np.ndarray) -> np.ndarray:
        """Returns keep-mask; inserts the kept digests into the filter.

        Also dedups within the incoming batch itself (first occurrence
        wins), exactly like a streaming crawler would.  The insert uses
        a fixed-shape padded batch with a valid count, so the jitted
        filter step compiles once per docs_per_step.  Ingest goes
        through ``filters.auto_scale``: growth is incremental where the
        family supports it (a flat-QF dedup filter migrates one bounded
        chunk per batch instead of re-streaming the whole table under
        one insert — mid-migration the cfg/state pair is the opaque
        migrating wrapper, and snapshots taken then restore and resume
        the migration), a cascade deepens its level stack in place, and
        the low watermark shrinks any of them back after heavy deletes.
        The pipeline never has to size the dedup filter for the corpus
        up front."""
        keys = jnp.asarray(doc_ids, jnp.uint32)
        seen = np.asarray(filters.contains(self.filter_cfg, self.filter_state, keys))
        _, first_idx = np.unique(doc_ids, return_index=True)
        first_occurrence = np.zeros(len(doc_ids), bool)
        first_occurrence[first_idx] = True
        keep = (~seen) & first_occurrence
        if keep.any():
            kept = doc_ids[keep]
            padded = np.zeros(len(doc_ids), np.uint32)
            padded[: len(kept)] = kept
            self.filter_cfg, self.filter_state = filters.auto_scale(
                self.filter_cfg,
                self.filter_state,
                jnp.asarray(padded),
                k=int(keep.sum()),
                chunk=self.cfg.dedup_chunk,
            )
        return keep

    def batches(self, n_batches: int, docs_per_step: int = 256) -> Iterator[dict]:
        """Yields training batches of packed token rows."""
        cfg = self.cfg
        need = cfg.seq_len * cfg.batch_size + 1
        backlog = self.state.token_backlog
        for _ in range(n_batches):
            while sum(len(t) for t in backlog) < need:
                ids, _ = self.corpus.batch(docs_per_step)
                keep = self._dedup(ids)
                self.state.docs_seen += len(ids)
                self.state.docs_kept += int(keep.sum())
                self.state.docs_dropped += int((~keep).sum())
                for d in ids[keep]:
                    backlog.append(self.corpus.tokens_for(int(d)))
            flat = np.concatenate(backlog)
            take = flat[:need]
            rest = flat[need - 1 :]  # keep one-token overlap for targets
            self.state.token_backlog = [rest]
            backlog = self.state.token_backlog
            rows = take[: cfg.seq_len * cfg.batch_size].reshape(
                cfg.batch_size, cfg.seq_len
            )
            tgts = take[1 : cfg.seq_len * cfg.batch_size + 1].reshape(
                cfg.batch_size, cfg.seq_len
            )
            yield {
                "tokens": jnp.asarray(rows, jnp.int32),
                "targets": jnp.asarray(tgts, jnp.int32),
            }

    # -- checkpointable state ------------------------------------------------

    def snapshot(self) -> dict:
        """Filter state is one pytree: flatten to np leaves (pickles cleanly).

        The filter config rides along (the NamedTuple itself — plain
        ints/floats/strings, pickles cleanly) because ``auto_scale``
        may have grown, shrunk, or mid-migrated the structure since
        construction — a restore must rebuild the *current* geometry,
        including an in-flight incremental-resize migration, not the
        configured starting one."""
        leaves = jax.tree_util.tree_leaves(self.filter_state)
        return {
            "docs_seen": self.state.docs_seen,
            "docs_kept": self.state.docs_kept,
            "docs_dropped": self.state.docs_dropped,
            "filter_cfg": self.filter_cfg,
            "filter_leaves": [np.asarray(leaf) for leaf in leaves],
        }

    @staticmethod
    def _blank_state(cfg):
        """An all-zero filter state with ``cfg``'s shapes (any family,
        including the in-flight migration wrapper)."""
        from repro.filters import incremental_resize

        if incremental_resize.is_migrating(cfg):
            return incremental_resize.blank(cfg)
        _, state = filters.make(filters.by_cfg(cfg).name, **cfg._asdict())
        return state

    def restore(self, snap: dict) -> None:
        self.state.docs_seen = int(snap["docs_seen"])
        self.state.docs_kept = int(snap["docs_kept"])
        self.state.docs_dropped = int(snap["docs_dropped"])
        cfg = snap.get("filter_cfg")
        if cfg is not None and not hasattr(cfg, "_fields"):
            # legacy (pre-PR4) snapshots stored tuple(cfg): reconstruct
            # as this pipeline's config type
            cfg = type(self.filter_cfg)(*cfg)
        if cfg is not None and (
            type(cfg) is not type(self.filter_cfg) or cfg != self.filter_cfg
        ):
            # build the blank state BEFORE touching self, so an invalid
            # snapshot cannot leave the pipeline half-restored
            state = self._blank_state(cfg)
            self.filter_cfg, self.filter_state = cfg, state
        cur = jax.tree_util.tree_leaves(self.filter_state)
        new = snap["filter_leaves"]
        if len(cur) != len(new) or any(
            a.shape != b.shape or a.dtype != b.dtype for a, b in zip(cur, new)
        ):
            raise ValueError(
                "snapshot filter state does not match this pipeline's dedup "
                "config (family/geometry changed?) — refusing to restore"
            )
        treedef = jax.tree_util.tree_structure(self.filter_state)
        self.filter_state = jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(leaf) for leaf in new]
        )
