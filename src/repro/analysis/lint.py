"""repro-lint engine: AST scopes, call graph, jit-reachability, baseline.

The engine parses every configured source file, splits it into function
*scopes* (one per ``def``, nested defs separate, plus a ``<module>``
pseudo-scope), builds a project-wide call graph (direct calls, calls
through import aliases, and bare-``Name`` references so higher-order
passage like ``lax.scan(step, ...)`` is followed), marks *jit roots* —

- functions decorated with ``jax.jit`` (bare, called, or via
  ``functools.partial(jax.jit, ...)``),
- functions passed to a ``jax.jit(...)`` or ``pl.pallas_call(...)``
  call,
- functions bound to a jittable op keyword of a ``FilterImpl(...)``
  registration (the façade's compiled surface — see
  ``filters/registry.py``),

— and BFS-propagates reachability.  Rules from
:mod:`repro.analysis.rules` then run per scope; findings inside
jit-reachable scopes are errors, host-side ones warnings, and both must
be fixed or carried in ``baseline.toml`` with a reason.
"""

from __future__ import annotations

import ast
import os
from collections import deque
from dataclasses import dataclass, field
from typing import Iterable, Optional

from . import toml_lite
from .rules import RULES, Finding, dotted_name

JITTABLE_OPS = {
    "insert",
    "contains",
    "delete",
    "merge",
    "probe",
    "stats",
    "needs_resize",
    "needs_shrink",
}

DEFAULT_PATHS = ["src/repro"]
DEFAULT_EXCLUDE = ["src/repro/analysis"]
DEFAULT_BASELINE = "src/repro/analysis/baseline.toml"


class Scope:
    def __init__(self, qualname: str, node: ast.AST, nodes: list[ast.AST]):
        self.qualname = qualname
        self.node = node
        self.nodes = nodes
        self.jit_root = False
        self.jit_reachable = False
        self.edges: set["Scope"] = set()
        self.param_names: set[str] = set()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = node.args
            self.param_names = {
                p.arg for p in [*a.posonlyargs, *a.args, *a.kwonlyargs]
            }
            if a.vararg:
                self.param_names.add(a.vararg.arg)
            if a.kwarg:
                self.param_names.add(a.kwarg.arg)

    def __repr__(self):  # pragma: no cover - debugging aid
        flags = "R" if self.jit_root else ("j" if self.jit_reachable else "-")
        return f"<Scope {self.qualname} {flags}>"


class FileContext:
    def __init__(
        self, path: str, modname: str, tree: ast.Module, is_package: bool = False
    ):
        self.path = path
        self.modname = modname
        self.is_package = is_package
        self.tree = tree
        self.np_aliases: set[str] = set()
        self.jnp_aliases: set[str] = set()
        self.jax_aliases: set[str] = set()
        self.dispatch_aliases: set[str] = set()
        self.dispatch_funcs: set[str] = set()  # from .dispatch import resolve
        self.jax_jit_names: set[str] = set()  # from jax import jit
        self.import_mods: dict[str, str] = {}  # local alias -> module path
        self.from_names: dict[str, tuple[str, str]] = {}  # name -> (mod, orig)
        self.static_roots: set[str] = set()
        self.state_roots: set[str] = {"state"}
        self.scopes: list[Scope] = []
        self._collect_imports()
        self._collect_scopes()

    # -- imports ----------------------------------------------------------
    def _resolve_relative(self, module: Optional[str], level: int) -> str:
        if not level:
            return module or ""
        parts = self.modname.split(".")
        if self.is_package:
            # from a package's __init__, level=1 is the package itself
            parts = parts + ["<pkg>"]
        base = parts[: len(parts) - level]
        return ".".join(base + (module.split(".") if module else []))

    def _note_module(self, alias: str, mod: str) -> None:
        self.import_mods[alias] = mod
        if mod == "numpy":
            self.np_aliases.add(alias)
        elif mod.startswith("jax.numpy"):
            self.jnp_aliases.add(alias)
        elif mod == "jax":
            self.jax_aliases.add(alias)
        elif mod.endswith(".dispatch") or mod == "dispatch":
            self.dispatch_aliases.add(alias)

    def _collect_imports(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    alias = a.asname or a.name.split(".")[0]
                    # `import jax.numpy as jnp` binds jnp to the submodule;
                    # plain `import jax.numpy` binds the root package
                    self._note_module(
                        alias, a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom):
                mod = self._resolve_relative(node.module, node.level)
                for a in node.names:
                    alias = a.asname or a.name
                    submod = f"{mod}.{a.name}" if mod else a.name
                    # `from pkg import name`: name may be a module or a
                    # function — record both interpretations
                    self._note_module(alias, submod)
                    self.from_names[alias] = (mod, a.name)
                    if mod == "jax" and a.name == "jit":
                        self.jax_jit_names.add(alias)
                    if mod.endswith("dispatch"):
                        self.dispatch_funcs.add(alias)

    # -- scopes -----------------------------------------------------------
    @staticmethod
    def _own_nodes(body: Iterable[ast.AST]) -> list[ast.AST]:
        """All nodes under `body`, not descending into nested defs."""
        out: list[ast.AST] = []
        stack = list(body)
        while stack:
            n = stack.pop()
            out.append(n)
            for c in ast.iter_child_nodes(n):
                if isinstance(c, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                stack.append(c)
        return out

    def _collect_scopes(self) -> None:
        module_body: list[ast.AST] = []

        def walk(nodes, prefix):
            for n in nodes:
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = f"{prefix}{n.name}"
                    self.scopes.append(
                        Scope(qual, n, self._own_nodes(n.body))
                    )
                    walk(n.body, f"{qual}.")
                elif isinstance(n, ast.ClassDef):
                    walk(n.body, f"{prefix}{n.name}.")
                    module_body.extend(
                        c
                        for c in n.body
                        if not isinstance(
                            c, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
                        )
                    )
                else:
                    if not prefix:
                        module_body.append(n)

        walk(self.tree.body, "")
        self.scopes.append(
            Scope("<module>", self.tree, self._own_nodes(module_body))
        )
        # module-level literal constants (SHRINK_LOAD = 0.4) are static
        for n in self.tree.body:
            if isinstance(n, ast.Assign) and _is_literal_node(n.value):
                for t in n.targets:
                    if isinstance(t, ast.Name):
                        self.static_roots.add(t.id)


class Project:
    """Cross-module call graph over a set of parsed files."""

    def __init__(self, sources: dict[str, str], src_prefix: str = "src"):
        self.files: dict[str, FileContext] = {}
        errors = []
        for path, text in sorted(sources.items()):
            modname = _modname_for(path, src_prefix)
            try:
                tree = ast.parse(text, filename=path)
            except SyntaxError as e:  # pragma: no cover - repo parses
                errors.append(f"{path}: syntax error: {e}")
                continue
            self.files[path] = FileContext(
                path, modname, tree, is_package=path.endswith("__init__.py")
            )
        self.parse_errors = errors
        # (modname, func trailing name) -> scopes
        self.func_index: dict[tuple[str, str], list[tuple[FileContext, Scope]]] = {}
        for ctx in self.files.values():
            for sc in ctx.scopes:
                tail = sc.qualname.rsplit(".", 1)[-1]
                if tail == "<module>":
                    continue
                self.func_index.setdefault((ctx.modname, tail), []).append((ctx, sc))
        self._build_edges_and_roots()
        self._propagate()

    # -- resolution -------------------------------------------------------
    def _targets(
        self,
        ctx: FileContext,
        name_node: ast.AST,
        shadowed: Optional[set] = None,
    ) -> list[Scope]:
        """Scopes a call/reference expression may land on."""
        fn = dotted_name(name_node)
        if fn is None:
            return []
        parts = fn.split(".")
        if len(parts) == 1:
            name = parts[0]
            if shadowed and name in shadowed:
                return []
            if name in ctx.from_names:
                mod, orig = ctx.from_names[name]
                hits = self.func_index.get((mod, orig), [])
                if hits:
                    return [sc for _, sc in hits]
            return [sc for _, sc in self.func_index.get((ctx.modname, name), [])]
        alias, name = parts[0], parts[-1]
        mod = ctx.import_mods.get(alias)
        if mod is None:
            return []
        return [sc for _, sc in self.func_index.get((mod, name), [])]

    def _build_edges_and_roots(self) -> None:
        for ctx in self.files.values():
            local = {
                sc.qualname.rsplit(".", 1)[-1]: sc
                for sc in ctx.scopes
                if sc.qualname != "<module>"
            }
            for sc in ctx.scopes:
                call_funcs = set()
                for n in sc.nodes:
                    if isinstance(n, ast.Call):
                        call_funcs.add(id(n.func))
                for n in sc.nodes:
                    if isinstance(n, ast.Call):
                        for t in self._targets(ctx, n.func, sc.param_names):
                            sc.edges.add(t)
                        self._mark_call_roots(ctx, n)
                    elif (
                        isinstance(n, ast.Name)
                        and isinstance(getattr(n, "ctx", None), ast.Load)
                        and id(n) not in call_funcs
                        and n.id not in sc.param_names
                        and n.id in local
                    ):
                        # bare reference: follow (higher-order passage)
                        sc.edges.add(local[n.id])
                if isinstance(sc.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if any(_is_jit_decorator(d, ctx) for d in sc.node.decorator_list):
                        sc.jit_root = True

    def _mark_call_roots(self, ctx: FileContext, call: ast.Call) -> None:
        fn = dotted_name(call.func)
        if fn is None:
            return
        tail = fn.rpartition(".")[2]
        if tail == "FilterImpl":
            for kw in call.keywords:
                if kw.arg in JITTABLE_OPS and kw.value is not None:
                    for sc in self._targets(ctx, kw.value):
                        sc.jit_root = True
        elif tail in ("jit", "pallas_call") and call.args:
            for sc in self._targets(ctx, call.args[0]):
                sc.jit_root = True

    def _propagate(self) -> None:
        q = deque(
            sc for ctx in self.files.values() for sc in ctx.scopes if sc.jit_root
        )
        for sc in q:
            sc.jit_reachable = True
        while q:
            sc = q.popleft()
            for t in sc.edges:
                if not t.jit_reachable:
                    t.jit_reachable = True
                    q.append(t)

    # -- rules ------------------------------------------------------------
    def run_rules(self) -> list[Finding]:
        findings: list[Finding] = []
        for path in sorted(self.files):
            ctx = self.files[path]
            for sc in ctx.scopes:
                for rule in RULES:
                    if rule.jit_only and not sc.jit_reachable:
                        continue
                    sev = rule.fixed_severity or (
                        "error" if sc.jit_reachable else "warning"
                    )
                    for line, msg in rule.visit(sc, ctx):
                        findings.append(
                            Finding(
                                rule=rule.id,
                                path=path,
                                line=line,
                                func=sc.qualname,
                                message=msg,
                                severity=sev,
                                hint=rule.hint,
                            )
                        )
        findings.sort(key=lambda f: (f.path, f.line, f.rule))
        return findings


def _is_literal_node(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, float, bool))
    if isinstance(node, ast.BinOp):
        return _is_literal_node(node.left) and _is_literal_node(node.right)
    if isinstance(node, ast.UnaryOp):
        return _is_literal_node(node.operand)
    return False


def _modname_for(path: str, src_prefix: str) -> str:
    p = path.replace(os.sep, "/")
    if p.startswith(src_prefix.rstrip("/") + "/"):
        p = p[len(src_prefix.rstrip("/")) + 1 :]
    if p.endswith("/__init__.py"):
        p = p[: -len("/__init__.py")]
    elif p.endswith(".py"):
        p = p[:-3]
    return p.replace("/", ".")


def _is_jit_decorator(dec: ast.AST, ctx: FileContext) -> bool:
    def is_jit(expr):
        fn = dotted_name(expr)
        if fn is None:
            return False
        base, _, attr = fn.rpartition(".")
        return (attr == "jit" and base in ctx.jax_aliases) or (
            not base and fn in ctx.jax_jit_names
        )

    if is_jit(dec):
        return True
    if isinstance(dec, ast.Call):
        if is_jit(dec.func):
            return True
        fn = dotted_name(dec.func)
        if fn and fn.rpartition(".")[2] == "partial" and dec.args:
            return is_jit(dec.args[0])
    return False


# --------------------------------------------------------------------------
# baseline


@dataclass
class BaselineEntry:
    rule: str
    path: str
    reason: str
    func: Optional[str] = None
    count: Optional[int] = None

    def matches(self, f: Finding) -> bool:
        if f.rule != self.rule or f.path != self.path:
            return False
        if self.func is not None:
            return f.func == self.func or f.func.startswith(self.func + ".")
        return True


def load_baseline(path: str) -> list[BaselineEntry]:
    if not os.path.exists(path):
        return []
    data = toml_lite.load_path(path)
    entries = []
    for i, raw in enumerate(data.get("allow", [])):
        try:
            e = BaselineEntry(
                rule=raw["rule"],
                path=raw["path"],
                reason=raw["reason"],
                func=raw.get("func"),
                count=raw.get("count"),
            )
        except KeyError as k:
            raise ValueError(
                f"{path}: allow entry #{i + 1} missing required key {k}"
            ) from None
        if not str(e.reason).strip():
            raise ValueError(
                f"{path}: allow entry #{i + 1} ({e.rule} {e.path}) has an "
                "empty reason — every baselined finding needs one"
            )
        entries.append(e)
    return entries


@dataclass
class LintResult:
    findings: list[Finding]  # unbaselined — these fail the run
    covered: int = 0
    stale: list[BaselineEntry] = field(default_factory=list)
    problems: list[str] = field(default_factory=list)
    n_files: int = 0
    n_scopes: int = 0
    n_jit_reachable: int = 0

    @property
    def ok(self) -> bool:
        return not self.findings and not self.problems


def apply_baseline(
    findings: list[Finding], entries: list[BaselineEntry]
) -> LintResult:
    pool = list(findings)
    covered = 0
    stale: list[BaselineEntry] = []
    problems: list[str] = []
    for e in entries:
        matched = [f for f in pool if e.matches(f)]
        if not matched:
            stale.append(e)
            continue
        if e.count is not None and len(matched) > e.count:
            problems.append(
                f"baseline entry {e.rule} {e.path}"
                + (f":{e.func}" if e.func else "")
                + f" allows {e.count} finding(s) but {len(matched)} matched — "
                "new violations appeared"
            )
        covered += len(matched)
        pool = [f for f in pool if not e.matches(f)]
    return LintResult(findings=pool, covered=covered, stale=stale, problems=problems)


# --------------------------------------------------------------------------
# config + entry points


@dataclass
class LintConfig:
    paths: list[str] = field(default_factory=lambda: list(DEFAULT_PATHS))
    exclude: list[str] = field(default_factory=lambda: list(DEFAULT_EXCLUDE))
    baseline: str = DEFAULT_BASELINE
    src_prefix: str = "src"


def load_config(root: str = ".") -> LintConfig:
    cfg = LintConfig()
    pj = os.path.join(root, "pyproject.toml")
    if os.path.exists(pj):
        data = toml_lite.load_path(pj)
        sec = data.get("tool", {}).get("repro-lint", {})
        cfg.paths = list(sec.get("paths", cfg.paths))
        cfg.exclude = list(sec.get("exclude", cfg.exclude))
        cfg.baseline = sec.get("baseline", cfg.baseline)
        cfg.src_prefix = sec.get("src-prefix", cfg.src_prefix)
    return cfg


def collect_sources(root: str, cfg: LintConfig) -> dict[str, str]:
    sources: dict[str, str] = {}
    excludes = [e.rstrip("/") for e in cfg.exclude]
    for base in cfg.paths:
        for dirpath, dirnames, filenames in os.walk(os.path.join(root, base)):
            rel_dir = os.path.relpath(dirpath, root).replace(os.sep, "/")
            if any(rel_dir == e or rel_dir.startswith(e + "/") for e in excludes):
                dirnames[:] = []
                continue
            for fn in filenames:
                if not fn.endswith(".py"):
                    continue
                rel = f"{rel_dir}/{fn}"
                with open(os.path.join(dirpath, fn), encoding="utf-8") as f:
                    sources[rel] = f.read()
    return sources


def analyze_sources(sources: dict[str, str], src_prefix: str = "src") -> list[Finding]:
    """Rule findings for in-memory sources (the test/fixture entry point)."""
    return Project(sources, src_prefix=src_prefix).run_rules()


def run_lint(root: str = ".", config: Optional[LintConfig] = None) -> LintResult:
    cfg = config or load_config(root)
    sources = collect_sources(root, cfg)
    project = Project(sources, src_prefix=cfg.src_prefix)
    findings = project.run_rules()
    entries, missing = [], []
    if cfg.baseline:
        bpath = os.path.join(root, cfg.baseline)
        if os.path.exists(bpath):
            entries = load_baseline(bpath)
        else:
            missing = [f"baseline file {cfg.baseline} not found"]
    result = apply_baseline(findings, entries)
    result.problems = project.parse_errors + missing + result.problems
    result.n_files = len(project.files)
    result.n_scopes = sum(len(c.scopes) for c in project.files.values())
    result.n_jit_reachable = sum(
        1 for c in project.files.values() for s in c.scopes if s.jit_reachable
    )
    return result


def render_report(result: LintResult, verbose: bool = False) -> str:
    lines = []
    for f in result.findings:
        lines.append(f.render())
        if verbose and f.hint:
            lines.append(f"    hint: {f.hint}")
    for e in result.stale:
        lines.append(
            f"note: stale baseline entry {e.rule} {e.path}"
            + (f":{e.func}" if e.func else "")
            + " matched nothing (consider removing)"
        )
    for p in result.problems:
        lines.append(f"error: {p}")
    lines.append(
        f"repro-lint: {result.n_files} files, {result.n_scopes} scopes "
        f"({result.n_jit_reachable} jit-reachable), "
        f"{len(result.findings)} finding(s), {result.covered} baselined"
    )
    return "\n".join(lines)
