"""jaxpr trace audit: every registry family's ops stay pure device programs.

For each registered filter family (small geometry, per-family spec
below) the audit traces ``insert / contains / delete / merge / probe /
needs_resize / needs_shrink`` through ``jax.make_jaxpr`` and records,
per op:

- **status** — ``traced`` (pure jaxpr), ``host`` (raises a tracer
  concretization error: the op is host-composed by design, e.g. the
  frozen cascade's peeling merge-down), ``unbound`` (family does not
  register the op), or ``unsupported`` (config-level refusal).
- **eqns** — recursive equation count (through pjit/cond/scan/switch
  sub-jaxprs), the audit's size fingerprint: a silent fallback from one
  fused program to an unrolled host loop shows up as a blow-up here.
- **prims** — recursive primitive histogram.  Callback and transfer
  primitives (``pure_callback``, ``io_callback``, ``debug_callback``,
  ``infeed``/``outfeed``, ``device_put``) are *forbidden* inside traced
  family ops and fail the audit outright — a new host round-trip cannot
  land silently.

The result diffs against the committed ``trace_manifest.json``:
status changes, new/removed ops, and eqn blow-ups (> ``BLOWUP`` x)
fail with a readable diff; primitive-set drift is informational (jax
versions move primitives around) unless ``--strict``.  Refresh with
``python -m repro.analysis trace --update`` after a reviewed change.
"""

from __future__ import annotations

import json
import os
from typing import Optional

import jax
import jax.numpy as jnp

MANIFEST_PATH = os.path.join(os.path.dirname(__file__), "trace_manifest.json")

OPS = (
    "insert",
    "contains",
    "delete",
    "merge",
    "probe",
    "needs_resize",
    "needs_shrink",
)

FORBIDDEN_PRIMITIVES = (
    "pure_callback",
    "io_callback",
    "debug_callback",
    "callback",
    "infeed",
    "outfeed",
    "device_put",
)

BLOWUP = 2.0  # traced-op eqn count may not exceed manifest * BLOWUP


def family_specs() -> dict[str, dict]:
    """Small, fast geometries — shapes only matter for tracing."""
    return {
        "qf": dict(q=8, r=8),
        "qf[pallas]": dict(q=8, r=8, backend="pallas"),
        "bloom": dict(m_bits=2048, k=4, counting=True),
        "blocked_bloom": dict(m_bits=65536, k=4, block_bits=32768, counting=True),
        "buffered_qf": dict(ram_q=6, disk_q=10, p=20),
        "cascade": dict(ram_q=6, p=20, levels=2),
        "cascade[pallas]": dict(ram_q=6, p=20, levels=2, backend="pallas"),
        "cascade[frozen]": dict(ram_q=6, p=24, levels=2, frozen_below=1),
        "sharded_qf": dict(q=8, r=8, n_shards=1),
        "xor_fuse": dict(capacity=128),
    }


def _keys(n: int = 64):
    # deterministic pseudo-random uint32 batch (Knuth multiplicative)
    mixed = jnp.arange(1, n + 1, dtype=jnp.uint32) * jnp.uint32(2654435761)
    return mixed ^ jnp.uint32(0x9E3779B9)


def _count_jaxpr(jaxpr) -> tuple[int, dict[str, int]]:
    """Recursive (eqn count, primitive histogram) through sub-jaxprs."""
    eqns = 0
    prims: dict[str, int] = {}

    def walk(jx):
        nonlocal eqns
        for eqn in jx.eqns:
            eqns += 1
            name = eqn.primitive.name
            prims[name] = prims.get(name, 0) + 1
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    walk(sub)

    walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return eqns, prims


def _sub_jaxprs(value):
    from jax.extend import core as jex_core  # jax >= 0.4.16

    jaxpr_types = (jex_core.Jaxpr, jex_core.ClosedJaxpr)
    if isinstance(value, jaxpr_types):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            if isinstance(v, jaxpr_types):
                yield v


_HOST_ERRORS = (
    jax.errors.ConcretizationTypeError,
    jax.errors.TracerArrayConversionError,
    jax.errors.TracerBoolConversionError,
)


def trace_family(fam: str, spec: dict) -> dict[str, dict]:
    from repro import filters
    from repro.filters.registry import UnsupportedOpError, by_cfg

    name = fam.split("[")[0]
    cfg, state = filters.make(name, **spec)
    impl = by_cfg(cfg)
    keys = _keys()
    out: dict[str, dict] = {}
    for op in OPS:
        fn = getattr(impl, op, None)
        if fn is None:
            out[op] = {"status": "unbound"}
            continue
        if op == "delete" and not impl.deletable(cfg):
            out[op] = {"status": "unsupported"}
            continue
        if op in ("insert", "contains", "delete", "probe"):
            thunk, args = (lambda s, ks, fn=fn: fn(cfg, s, ks)), (state, keys)
        elif op == "merge":
            thunk, args = (lambda sa, sb, fn=fn: fn(cfg, sa, sb)), (state, state)
        else:  # needs_resize / needs_shrink
            thunk, args = (lambda s, fn=fn: fn(cfg, s)), (state,)
        try:
            jaxpr = jax.make_jaxpr(thunk)(*args)
        except _HOST_ERRORS:
            out[op] = {"status": "host"}
            continue
        except UnsupportedOpError:
            out[op] = {"status": "unsupported"}
            continue
        except Exception as e:  # noqa: BLE001 - audited + surfaced below
            out[op] = {"status": "error", "error": f"{type(e).__name__}: {e}"}
            continue
        eqns, prims = _count_jaxpr(jaxpr)
        out[op] = {"status": "traced", "eqns": eqns, "prims": prims}
    return out


def collect(families: Optional[list[str]] = None) -> dict:
    specs = family_specs()
    if families:
        specs = {
            k: v
            for k, v in specs.items()
            if k.split("[")[0] in families or k in families
        }
    return {"families": {fam: trace_family(fam, spec) for fam, spec in specs.items()}}


def forbidden_hits(current: dict) -> list[str]:
    hits = []
    for fam, ops in current["families"].items():
        for op, entry in ops.items():
            for prim, n in entry.get("prims", {}).items():
                if any(f in prim for f in FORBIDDEN_PRIMITIVES):
                    hits.append(
                        f"{fam}.{op}: forbidden primitive {prim!r} x{n} — a "
                        "traced family op performs a host callback/transfer"
                    )
    return hits


def errors(current: dict) -> list[str]:
    out = []
    for fam, ops in current["families"].items():
        for op, entry in ops.items():
            if entry["status"] == "error":
                out.append(f"{fam}.{op}: trace raised {entry['error']}")
    return out


def diff(current: dict, manifest: dict, strict: bool = False) -> tuple[list[str], bool]:
    """Readable diff lines + pass/fail against the committed manifest."""
    lines: list[str] = []
    failed = False
    cur, man = current["families"], manifest.get("families", {})
    for fam in sorted(set(cur) | set(man)):
        if fam not in man:
            lines.append(f"FAIL {fam}: new family not in manifest (run --update)")
            failed = True
            continue
        if fam not in cur:
            lines.append(f"FAIL {fam}: in manifest but no longer traced (run --update)")
            failed = True
            continue
        for op in sorted(set(cur[fam]) | set(man[fam])):
            c, m = cur[fam].get(op), man[fam].get(op)
            if m is None:
                lines.append(f"FAIL {fam}.{op}: new op not in manifest (run --update)")
                failed = True
                continue
            if c is None:
                lines.append(f"FAIL {fam}.{op}: op disappeared (run --update)")
                failed = True
                continue
            if c["status"] != m["status"]:
                lines.append(
                    f"FAIL {fam}.{op}: status {m['status']} -> {c['status']} — "
                    "a traced op degrading to host (or vice versa) must be a "
                    "reviewed change (run --update after review)"
                )
                failed = True
                continue
            if c["status"] != "traced":
                continue
            if c["eqns"] > m["eqns"] * BLOWUP:
                lines.append(
                    f"FAIL {fam}.{op}: eqn count {m['eqns']} -> {c['eqns']} "
                    f"(> {BLOWUP:.1f}x blow-up — fused program degraded?)"
                )
                failed = True
            added = set(c["prims"]) - set(m["prims"])
            removed = set(m["prims"]) - set(c["prims"])
            if added or removed:
                note = (
                    f"{'FAIL' if strict else 'note'} {fam}.{op}: primitive set "
                    f"drift (+{sorted(added)} -{sorted(removed)})"
                )
                lines.append(note)
                failed = failed or strict
    return lines, not failed


def load_manifest(path: str = MANIFEST_PATH) -> Optional[dict]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def write_manifest(current: dict, path: str = MANIFEST_PATH) -> None:
    payload = {
        "comment": (
            "Committed jaxpr trace manifest (see repro.analysis.trace_audit). "
            "Refresh with `python -m repro.analysis trace --update` after a "
            "reviewed change; bypass one CI run with [trace-skip]."
        ),
        "families": current["families"],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def render_summary(current: dict) -> str:
    lines = []
    for fam, ops in sorted(current["families"].items()):
        for op, entry in sorted(ops.items()):
            extra = ""
            if entry["status"] == "traced":
                pjits = sum(
                    n for p, n in entry["prims"].items() if p in ("pjit", "xla_call")
                )
                extra = f" eqns={entry['eqns']} pjit={pjits}"
            lines.append(f"  {fam + '.' + op:40s} {entry['status']}{extra}")
    return "\n".join(lines)


def run_audit(
    update: bool = False,
    strict: bool = False,
    manifest_path: str = MANIFEST_PATH,
    verbose: bool = False,
) -> int:
    current = collect()
    problems = errors(current) + forbidden_hits(current)
    if verbose:
        print(render_summary(current))
    for p in problems:
        print(f"FAIL {p}")
    if update:
        if problems:
            print("trace-audit: refusing to --update a failing trace")
            return 1
        write_manifest(current, manifest_path)
        n_tr = sum(
            1
            for ops in current["families"].values()
            for e in ops.values()
            if e["status"] == "traced"
        )
        print(f"trace-audit: manifest refreshed ({n_tr} traced ops) -> {manifest_path}")
        return 0
    manifest = load_manifest(manifest_path)
    if manifest is None:
        print(f"trace-audit: no manifest at {manifest_path} (run --update)")
        return 1
    lines, ok = diff(current, manifest, strict=strict)
    for line in lines:
        print(line)
    n_ops = sum(len(ops) for ops in current["families"].values())
    verdict = "passed" if ok and not problems else "FAILED"
    print(
        f"trace-audit {verdict}: {len(current['families'])} families, "
        f"{n_ops} ops audited"
    )
    return 0 if ok and not problems else 1
