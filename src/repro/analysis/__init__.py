"""Static analysis for the repro: trace-safety + kernel-contract checks.

Three cooperating analyzers, runnable as ``python -m repro.analysis``
(see ``__main__``) and as the CI ``analysis`` job:

- :mod:`repro.analysis.lint` — **repro-lint**, an AST rule engine that
  flags host-sync constructs inside jit-reachable code (rule classes in
  :mod:`repro.analysis.rules`, registered like ``filters/registry.py``
  impls), with a committed per-file allowlist ``baseline.toml``.
- :mod:`repro.analysis.trace_audit` — traces every registry family's
  ops via ``jax.make_jaxpr``, asserts zero callback/transfer
  primitives, and diffs primitive counts against the committed
  ``trace_manifest.json``.
- :mod:`repro.analysis.spec_check` — statically validates every Pallas
  kernel's grid/BlockSpec metadata (index maps in bounds, tiles divide
  planes, scalar-prefetch counts match) and that each kernel has a
  bound ``kernels/ref.py`` oracle and a parity test.
"""
