"""repro-lint rules: each a small class with id, severity and fix hint.

Rules are registered like filter impls in ``filters/registry.py`` — a
module-level registry that :mod:`repro.analysis.lint` iterates.  Each
rule's :meth:`Rule.visit` walks one function scope (the AST nodes owned
by a single ``def``, nested defs excluded) and yields
``(lineno, message)`` violations; the engine attaches file / function /
jit-reachability context and severity.

Rule ids (stable — referenced from ``baseline.toml``):

- **RL101** ``.item()`` / ``.tolist()`` host sync
- **RL102** ``int()`` / ``float()`` / ``bool()`` on a traced value
- **RL103** numpy host round-trip (``np.asarray`` / ``np.array`` /
  ``jax.device_get``)
- **RL104** Python ``if`` / ``while`` branching on a device scalar
- **RL105** kernel-mode resolution inside jit-reachable code (the PR-7
  stale-jit-cache bug class)
- **RL106** bare int32-range literal compared without an explicit dtype
  (the PR-3 sentinel-wrap bug class)
- **RL107** state-threading ``jax.jit`` without ``donate_argnums``
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Iterator, Optional


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    func: str  # dotted in-file qualname; "<module>" for top-level code
    message: str
    severity: str  # "error" (jit-reachable) | "warning"
    hint: str

    def render(self) -> str:
        return (
            f"{self.path}:{self.line}: {self.rule} [{self.severity}] "
            f"{self.message}  (in {self.func})"
        )


class Rule:
    """Base rule.  Subclasses set the class attrs and implement visit."""

    id: str = "RL000"
    title: str = ""
    hint: str = ""
    # True: only report inside jit-reachable scopes (construct is fine on
    # the host); False: report everywhere, severity by reachability.
    jit_only: bool = False
    # non-None: severity is fixed instead of derived from reachability
    fixed_severity: Optional[str] = None

    def visit(self, scope: "Scope", ctx: "FileContext") -> Iterator[tuple[int, str]]:
        raise NotImplementedError


RULES: list[Rule] = []


def register(cls: type) -> type:
    RULES.append(cls())
    return cls


def rule_by_id(rule_id: str) -> Rule:
    for r in RULES:
        if r.id == rule_id:
            return r
    raise KeyError(f"unknown rule {rule_id!r}; known: {[r.id for r in RULES]}")


# --------------------------------------------------------------------------
# shared AST helpers


def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def root_name(node: ast.AST) -> Optional[str]:
    """Leftmost Name of an arbitrary expression chain (calls/subscripts ok)."""
    while True:
        if isinstance(node, ast.Attribute):
            node = node.value
        elif isinstance(node, (ast.Subscript, ast.Call)):
            node = node.func if isinstance(node, ast.Call) else node.value
        elif isinstance(node, ast.Name):
            return node.id
        else:
            return None


_SENTINELS = {2147483647, 2147483648, -2147483648, 4294967295}

_DTYPE_WRAPPERS = {
    "int32",
    "uint32",
    "int64",
    "uint64",
    "asarray",
    "array",
    "full",
    "full_like",
    "astype",
    "constant",
}

# cfg-ish roots whose attributes are static python scalars by protocol
# (configs are hashable NamedTuples — jit-static by construction)
_STATIC_ROOT_SUFFIXES = ("cfg", "spec", "math")

_HOST_CAST_SAFE_CALLS = {"len", "round", "abs", "min", "max", "ord", "pow", "sum"}


def _is_static_expr(node: ast.AST, ctx: "FileContext") -> bool:
    """Conservatively: does this expression never hold a traced value?"""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Attribute):
        final_static = node.attr in {"shape", "ndim", "size", "dtype"}
        root = root_name(node)
        root_static = root is not None and (
            root.endswith(_STATIC_ROOT_SUFFIXES) or root in ctx.static_roots
        )
        return final_static or root_static
    if isinstance(node, ast.Name):
        return node.id in ctx.static_roots or node.id.endswith(_STATIC_ROOT_SUFFIXES)
    if isinstance(node, ast.Subscript):
        return _is_static_expr(node.value, ctx)
    if isinstance(node, ast.Call):
        fn = dotted_name(node.func)
        if fn is None:
            return False
        base = fn.split(".")[0]
        if fn in _HOST_CAST_SAFE_CALLS or base == "math":
            if fn == "len":
                return True  # len() of anything is a host int
            return all(_is_static_expr(a, ctx) for a in node.args)
        if base.endswith(_STATIC_ROOT_SUFFIXES) or base in ctx.static_roots:
            # method on a static config (cfg.slots(), spec.total_bits())
            return all(_is_static_expr(a, ctx) for a in node.args)
        if "." not in fn and node.args:
            # local helper on static-only args (geometry math like
            # _cells(cfg)); device values enter through state/keys args
            return all(_is_static_expr(a, ctx) for a in node.args)
        return False
    if isinstance(node, ast.BinOp):
        return _is_static_expr(node.left, ctx) and _is_static_expr(node.right, ctx)
    if isinstance(node, ast.UnaryOp):
        return _is_static_expr(node.operand, ctx)
    if isinstance(node, ast.BoolOp):
        return all(_is_static_expr(v, ctx) for v in node.values)
    if isinstance(node, ast.Compare):
        return _is_static_expr(node.left, ctx) and all(
            _is_static_expr(c, ctx) for c in node.comparators
        )
    if isinstance(node, ast.IfExp):
        return (
            _is_static_expr(node.test, ctx)
            and _is_static_expr(node.body, ctx)
            and _is_static_expr(node.orelse, ctx)
        )
    return False


def _is_literal_arith(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, float))
    if isinstance(node, ast.BinOp):
        return _is_literal_arith(node.left) and _is_literal_arith(node.right)
    if isinstance(node, ast.UnaryOp):
        return _is_literal_arith(node.operand)
    return False


def _contains_sentinel_literal(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and sub.value in _SENTINELS:
            return True
        if isinstance(sub, ast.BinOp):
            lo, hi = sub.left, sub.right
            if (
                isinstance(sub.op, (ast.Pow, ast.LShift))
                and isinstance(lo, ast.Constant)
                and isinstance(hi, ast.Constant)
                and lo.value in (1, 2)
                and hi.value in (31, 32)
            ):
                return True
    return False


# --------------------------------------------------------------------------
# rules


@register
class HostItemCall(Rule):
    id = "RL101"
    title = "device-to-host .item()/.tolist() sync"
    hint = (
        "keep the value on device (jnp ops compose under jit); if a host "
        "scalar is genuinely needed, move the sync to the host driver and "
        "baseline it with a reason"
    )

    def visit(self, scope, ctx):
        for node in scope.nodes:
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("item", "tolist")
                and not node.args
                and not node.keywords
            ):
                yield node.lineno, f".{node.func.attr}() forces a host sync"


@register
class HostScalarCast(Rule):
    id = "RL102"
    title = "int()/float()/bool() on a traced value"
    hint = (
        "use jnp casts / lax.cond / jnp.where on device; under jit this "
        "either fails to trace or silently freezes a traced value"
    )

    def visit(self, scope, ctx):
        for node in scope.nodes:
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id in ("int", "float", "bool")
                and len(node.args) == 1
                and not node.keywords
                and not _is_static_expr(node.args[0], ctx)
            ):
                yield (
                    node.lineno,
                    f"{node.func.id}() on a potentially traced value forces "
                    "a host sync",
                )


@register
class NumpyHostRoundTrip(Rule):
    id = "RL103"
    title = "numpy host round-trip"
    hint = (
        "np.asarray/np.array/jax.device_get pull the buffer to host RAM; "
        "stay in jnp, or baseline genuinely host-side code with a reason"
    )

    def visit(self, scope, ctx):
        for node in scope.nodes:
            if not isinstance(node, ast.Call):
                continue
            fn = dotted_name(node.func)
            if fn is None:
                continue
            base, _, attr = fn.rpartition(".")
            if base in ctx.np_aliases and attr in ("asarray", "array"):
                yield node.lineno, f"{fn}() copies the device buffer to host"
            elif base in ctx.jax_aliases and attr == "device_get":
                yield node.lineno, f"{fn}() copies the device buffer to host"


@register
class PythonBranchOnDevice(Rule):
    id = "RL104"
    title = "Python if/while on a device scalar"
    jit_only = True
    hint = (
        "a Python branch on a traced value raises TracerBoolConversionError "
        "under jit; use lax.cond / lax.while_loop / jnp.where"
    )

    def visit(self, scope, ctx):
        for node in scope.nodes:
            if not isinstance(node, (ast.If, ast.While)):
                continue
            test = node.test
            # int()/bool() casts in the test are RL102's finding
            if any(
                isinstance(s, ast.Call)
                and isinstance(s.func, ast.Name)
                and s.func.id in ("int", "float", "bool")
                for s in ast.walk(test)
            ):
                continue
            devicey = False
            for s in ast.walk(test):
                if isinstance(s, ast.Call):
                    fn = dotted_name(s.func)
                    if fn and fn.split(".")[0] in ctx.jnp_aliases:
                        devicey = True
                if isinstance(s, ast.Attribute) and root_name(s) in ctx.state_roots:
                    devicey = True
            if devicey:
                kw = "if" if isinstance(node, ast.If) else "while"
                yield node.lineno, f"Python `{kw}` on a device value"


@register
class KernelModeResolveInTrace(Rule):
    id = "RL105"
    title = "kernel-mode resolution inside jit-reachable code"
    jit_only = True
    hint = (
        "resolve the mode eagerly outside jit (kernels/dispatch.resolve in "
        "the un-jitted wrapper) and pass it as a static arg — resolving "
        "inside a traced region bakes the boot-time env into the jit cache "
        "(the PR-7 bug class)"
    )

    def visit(self, scope, ctx):
        for node in scope.nodes:
            if isinstance(node, ast.Call):
                fn = dotted_name(node.func)
                if fn is None:
                    continue
                base, _, attr = fn.rpartition(".")
                if attr in ("resolve", "default_mode") and (
                    base in ctx.dispatch_aliases
                    or (not base and fn in ctx.dispatch_funcs)
                ):
                    yield (
                        node.lineno,
                        f"{fn}() resolves kernel mode inside jit-reachable code",
                    )
            elif isinstance(node, ast.Constant) and node.value == "REPRO_KERNEL_MODE":
                yield (
                    node.lineno,
                    "REPRO_KERNEL_MODE read inside jit-reachable code",
                )


@register
class BareInt32Sentinel(Rule):
    id = "RL106"
    title = "bare int32-range literal in a comparison"
    hint = (
        "wrap sentinels in an explicit dtype (jnp.int32(2**31 - 1)) or use "
        "the module constant (qf.INT32_MAX); a bare literal promotes per "
        "numpy rules and can flip sign on the int32 fingerprint planes"
    )

    def visit(self, scope, ctx):
        for node in scope.nodes:
            if not isinstance(node, ast.Compare):
                continue
            for side in [node.left, *node.comparators]:
                if not _is_literal_arith(side):
                    continue
                if _contains_sentinel_literal(side):
                    yield (
                        side.lineno,
                        "int32-range literal compared without an explicit "
                        "dtype wrap",
                    )


@register
class JitMissingDonate(Rule):
    id = "RL107"
    title = "state-threading jax.jit without donate_argnums"
    fixed_severity = "warning"
    hint = (
        "a jit that rebuilds its state pytree should donate the input "
        "buffers (donate_argnums=/donate_argnames=) so the old planes are "
        "reused instead of copied — unless callers must keep snapshots"
    )

    _DONATE_KWS = ("donate_argnums", "donate_argnames")

    def _jit_call_kwargs(self, node: ast.AST, ctx) -> Optional[list[ast.keyword]]:
        """keywords of a jit-constructing decorator/call, else None."""
        if not isinstance(node, ast.Call):
            fn = dotted_name(node)
            if fn is not None and self._is_jit_name(fn, ctx):
                return []  # bare @jax.jit — no kwargs at all
            return None
        fn = dotted_name(node.func)
        if fn is None:
            return None
        if self._is_jit_name(fn, ctx):
            return node.keywords
        # functools.partial(jax.jit, static_argnums=...)
        if fn.rpartition(".")[2] == "partial" and node.args:
            inner = dotted_name(node.args[0])
            if inner is not None and self._is_jit_name(inner, ctx):
                return node.keywords
        return None

    @staticmethod
    def _is_jit_name(fn: str, ctx) -> bool:
        base, _, attr = fn.rpartition(".")
        return (attr == "jit" and base in ctx.jax_aliases) or (
            not base and fn in ctx.jax_jit_names
        )

    @staticmethod
    def _threads_state(fndef: ast.FunctionDef) -> bool:
        state_params = {
            a.arg
            for a in [*fndef.args.posonlyargs, *fndef.args.args, *fndef.args.kwonlyargs]
            if a.arg == "state" or a.arg.endswith(("_state", "states"))
        }
        if not state_params:
            return False
        for node in ast.walk(fndef):
            # writes: state._replace(...), state.field.at[...], or a
            # *State(...) constructor — reads alone need no donation
            if isinstance(node, ast.Attribute):
                if node.attr in ("_replace", "at") and root_name(node) in state_params:
                    return True
            if isinstance(node, ast.Call):
                fn = dotted_name(node.func)
                if fn is not None and fn.rpartition(".")[2].endswith("State"):
                    return True
        return False

    def visit(self, scope, ctx):
        node = scope.node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return
        for dec in node.decorator_list:
            kws = self._jit_call_kwargs(dec, ctx)
            if kws is None:
                continue
            if any(kw.arg in self._DONATE_KWS for kw in kws):
                return
            if self._threads_state(node):
                yield (
                    dec.lineno,
                    f"jit of {node.name}() rebuilds its state without "
                    "donate_argnums",
                )
            return
