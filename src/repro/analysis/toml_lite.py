"""TOML loading that works on py3.10 (no stdlib ``tomllib``).

Uses ``tomllib`` when available; otherwise a fallback parser covering
the subset this repo's config files actually use: ``[section]`` /
``[[array-of-tables]]`` headers (dotted and quoted keys), string / int /
float / bool scalars, and (possibly multi-line) arrays of scalars.
Inline tables and date-times are out of scope and raise.
"""

from __future__ import annotations

from typing import Any

try:  # py >= 3.11
    import tomllib as _tomllib
except ImportError:  # pragma: no cover - exercised on the py3.10 CI leg
    _tomllib = None


class TomlError(ValueError):
    pass


def load_path(path: str) -> dict:
    with open(path, "rb") as f:
        data = f.read()
    if _tomllib is not None:
        return _tomllib.loads(data.decode("utf-8"))
    return loads(data.decode("utf-8"))


def loads(text: str) -> dict:
    if _tomllib is not None:
        return _tomllib.loads(text)
    return _loads_fallback(text)


def _strip_comment(line: str) -> str:
    out = []
    quote = None
    for ch in line:
        if quote:
            if ch == quote:
                quote = None
        elif ch in "\"'":
            quote = ch
        elif ch == "#":
            break
        out.append(ch)
    return "".join(out).strip()


def _split_key(raw: str) -> list[str]:
    """Split a (possibly dotted, possibly quoted) TOML key."""
    parts: list[str] = []
    buf: list[str] = []
    quote = None
    for ch in raw:
        if quote:
            if ch == quote:
                quote = None
            else:
                buf.append(ch)
        elif ch in "\"'":
            quote = ch
        elif ch == ".":
            parts.append("".join(buf).strip())
            buf = []
        else:
            buf.append(ch)
    parts.append("".join(buf).strip())
    if quote or any(not p for p in parts):
        raise TomlError(f"malformed key: {raw!r}")
    return parts


def _parse_scalar(tok: str) -> Any:
    tok = tok.strip()
    if not tok:
        raise TomlError("empty value")
    if tok[0] in "\"'":
        if len(tok) < 2 or tok[-1] != tok[0]:
            raise TomlError(f"unterminated string: {tok!r}")
        body = tok[1:-1]
        if tok[0] == '"':
            body = (
                body.replace("\\\\", "\0")
                .replace('\\"', '"')
                .replace("\\n", "\n")
                .replace("\\t", "\t")
                .replace("\0", "\\")
            )
        return body
    if tok == "true":
        return True
    if tok == "false":
        return False
    try:
        return int(tok, 0)
    except ValueError:
        pass
    try:
        return float(tok)
    except ValueError:
        raise TomlError(f"unsupported value: {tok!r}") from None


def _split_array_items(body: str) -> list[str]:
    items: list[str] = []
    buf: list[str] = []
    quote = None
    depth = 0
    for ch in body:
        if quote:
            buf.append(ch)
            if ch == quote:
                quote = None
        elif ch in "\"'":
            quote = ch
            buf.append(ch)
        elif ch == "[":
            depth += 1
            buf.append(ch)
        elif ch == "]":
            depth -= 1
            buf.append(ch)
        elif ch == "," and depth == 0:
            items.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    if "".join(buf).strip():
        items.append("".join(buf))
    return [it.strip() for it in items if it.strip()]


def _parse_value(tok: str) -> Any:
    tok = tok.strip()
    if tok.startswith("["):
        if not tok.endswith("]"):
            raise TomlError(f"unterminated array: {tok!r}")
        return [_parse_value(item) for item in _split_array_items(tok[1:-1])]
    if tok.startswith("{"):
        raise TomlError("inline tables are not supported by the fallback parser")
    return _parse_scalar(tok)


def _descend(root: dict, parts: list[str], *, array_tail: bool) -> dict:
    cur = root
    for p in parts[:-1]:
        nxt = cur.setdefault(p, {})
        if isinstance(nxt, list):
            nxt = nxt[-1]
        cur = nxt
    last = parts[-1]
    if array_tail:
        arr = cur.setdefault(last, [])
        if not isinstance(arr, list):
            raise TomlError(f"{'.'.join(parts)} is not an array of tables")
        arr.append({})
        return arr[-1]
    nxt = cur.setdefault(last, {})
    if isinstance(nxt, list):
        nxt = nxt[-1]
    return nxt


def _loads_fallback(text: str) -> dict:
    root: dict = {}
    table = root
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        line = _strip_comment(lines[i])
        i += 1
        if not line:
            continue
        if line.startswith("[["):
            if not line.endswith("]]"):
                raise TomlError(f"malformed table header: {line!r}")
            table = _descend(root, _split_key(line[2:-2]), array_tail=True)
            continue
        if line.startswith("["):
            if not line.endswith("]"):
                raise TomlError(f"malformed table header: {line!r}")
            table = _descend(root, _split_key(line[1:-1]), array_tail=False)
            continue
        if "=" not in line:
            raise TomlError(f"expected key = value: {line!r}")
        key_raw, val_raw = line.split("=", 1)
        # multi-line array: accumulate until brackets balance outside strings
        while _bracket_depth(val_raw) > 0:
            if i >= len(lines):
                raise TomlError(f"unterminated array for key {key_raw.strip()!r}")
            val_raw += " " + _strip_comment(lines[i])
            i += 1
        keys = _split_key(key_raw.strip())
        target = table
        for p in keys[:-1]:
            nxt = target.setdefault(p, {})
            if isinstance(nxt, list):
                nxt = nxt[-1]
            target = nxt
        target[keys[-1]] = _parse_value(val_raw.strip())
    return root


def _bracket_depth(s: str) -> int:
    depth = 0
    quote = None
    for ch in s:
        if quote:
            if ch == quote:
                quote = None
        elif ch in "\"'":
            quote = ch
        elif ch == "[":
            depth += 1
        elif ch == "]":
            depth -= 1
    return depth
