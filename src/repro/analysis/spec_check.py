"""Pallas kernel-contract spec checker.

Every Pallas kernel module in ``repro.kernels`` publishes a wrapper
that assembles a grid spec (scalar-prefetch refs, windowed
``BlockSpec``s, query tiles) and launches ``pl.pallas_call``.  The
contract between the wrapper and the kernel body is entirely
structural — ref counts, block shapes, index-map ranges — and a
mismatch surfaces at trace time at best, or as silent garbage reads
(a window index map stepping off the padded plane) at worst.

This checker validates the contract *statically*, without executing a
single kernel program: it monkey-patches ``pl.pallas_call`` to capture
``(kernel_fn, grid_spec, out_shape, operands)``, drives each wrapper
with a tiny synthetic geometry, and checks each captured launch:

- operand count == ``num_scalar_prefetch`` + ``len(in_specs)``, and the
  kernel body's positional arity covers scalars + inputs + outputs;
- every ``BlockSpec`` tile shape divides its operand's plane shape
  (no partial edge blocks — the kernels assume whole windows);
- every index map, evaluated over the FULL grid in block units with
  the concrete scalar-prefetch values, stays in bounds for its operand
  (this is exactly the clipping invariant the wrappers' ``jnp.clip`` /
  ``minimum`` guards exist to uphold);
- the same for ``out_specs`` against ``out_shape``.

Separately it checks each kernel's *bindings*: the declared pure-jnp
oracle exists in ``repro.kernels.ref`` and both the wrapper and the
oracle appear in at least one test under ``tests/`` (a parity test the
kernel cannot silently lose).
"""

from __future__ import annotations

import dataclasses
import inspect
import itertools
import os
from typing import Callable, Optional

import numpy as np
import jax.numpy as jnp


# --------------------------------------------------------------------------
# capture


@dataclasses.dataclass
class CapturedCall:
    """One intercepted ``pl.pallas_call`` launch, reduced to structure."""

    kernel_name: str
    kernel_params: Optional[int]  # None when the body takes *refs
    grid: tuple
    num_scalar_prefetch: int
    in_specs: list
    out_specs: list
    operand_shapes: list  # tensor operands (scalar-prefetch args excluded)
    scalar_values: list  # concrete scalar-prefetch arrays (numpy)
    out_shapes: list  # (shape, dtype) per output


def _positional_arity(fn) -> Optional[int]:
    params = list(inspect.signature(fn).parameters.values())
    if any(p.kind == inspect.Parameter.VAR_POSITIONAL for p in params):
        return None
    return len(params)


def capture_kernel_calls(driver: Callable[[], None]) -> list[CapturedCall]:
    """Run ``driver`` with ``pl.pallas_call`` replaced by a recorder.

    The recorder returns zero arrays of the declared ``out_shape`` so
    wrapper post-processing (reshapes, overflow ORs) still runs; no
    kernel program is traced or executed.
    """
    from jax.experimental import pallas as pl

    captured: list[CapturedCall] = []
    real = pl.pallas_call

    def fake_pallas_call(kernel, *, grid_spec=None, out_shape=None, **kw):
        shapes = out_shape if isinstance(out_shape, (list, tuple)) else [out_shape]

        def launch(*operands):
            nsp = getattr(grid_spec, "num_scalar_prefetch", 0)
            captured.append(
                CapturedCall(
                    kernel_name=getattr(kernel, "__name__", repr(kernel)),
                    kernel_params=_positional_arity(kernel),
                    grid=tuple(getattr(grid_spec, "grid", ())),
                    num_scalar_prefetch=nsp,
                    in_specs=list(getattr(grid_spec, "in_specs", [])),
                    out_specs=list(getattr(grid_spec, "out_specs", [])),
                    operand_shapes=[tuple(o.shape) for o in operands[nsp:]],
                    scalar_values=[np.asarray(o) for o in operands[:nsp]],
                    out_shapes=[(tuple(s.shape), s.dtype) for s in shapes],
                )
            )
            outs = [jnp.zeros(s.shape, s.dtype) for s in shapes]
            return outs if isinstance(out_shape, (list, tuple)) else outs[0]

        return launch

    pl.pallas_call = fake_pallas_call
    try:
        driver()
    finally:
        pl.pallas_call = real
    return captured


# --------------------------------------------------------------------------
# validation


def _block_shape(spec):
    bs = getattr(spec, "block_shape", None)
    return tuple(bs) if bs is not None else None


def _index_map(spec):
    return getattr(spec, "index_map", None)


def validate_call(call: CapturedCall) -> list[str]:
    """Structural problems with one captured launch (empty = clean)."""
    problems: list[str] = []
    k = call.kernel_name

    if not call.grid or any(g <= 0 for g in call.grid):
        problems.append(f"{k}: empty/degenerate grid {call.grid}")
        return problems

    n_in = len(call.in_specs)
    n_out = len(call.out_specs)
    if len(call.operand_shapes) != n_in:
        problems.append(
            f"{k}: {len(call.operand_shapes)} tensor operands for {n_in} "
            f"in_specs (num_scalar_prefetch={call.num_scalar_prefetch} — "
            "scalar-prefetch ref count out of step with the call site?)"
        )
        return problems
    if call.kernel_params is not None:
        want = call.num_scalar_prefetch + n_in + n_out
        if call.kernel_params != want:
            problems.append(
                f"{k}: kernel body takes {call.kernel_params} refs but the "
                f"grid spec binds {want} "
                f"({call.num_scalar_prefetch} scalar + {n_in} in + {n_out} out)"
            )

    grid_points = list(itertools.product(*(range(g) for g in call.grid)))

    def check_spec(spec, shape, role, idx):
        bs = _block_shape(spec)
        if bs is None:
            problems.append(f"{k}: {role}[{idx}] has no block_shape")
            return
        if len(bs) != len(shape):
            problems.append(
                f"{k}: {role}[{idx}] block rank {len(bs)} != operand rank "
                f"{len(shape)} (shape {shape})"
            )
            return
        for d, (b, s) in enumerate(zip(bs, shape)):
            if b <= 0 or s % b != 0:
                problems.append(
                    f"{k}: {role}[{idx}] tile dim {d} ({b}) does not divide "
                    f"plane dim ({s}) — partial edge block"
                )
                return
        imap = _index_map(spec)
        if imap is None:
            problems.append(f"{k}: {role}[{idx}] has no index_map")
            return
        nblocks = tuple(s // b for s, b in zip(shape, bs))
        for point in grid_points:
            try:
                out = imap(*point, *call.scalar_values)
            except Exception as e:  # noqa: BLE001 - reported, not raised
                problems.append(
                    f"{k}: {role}[{idx}] index_map raised at grid {point}: "
                    f"{type(e).__name__}: {e}"
                )
                return
            out = tuple(int(v) for v in (out if isinstance(out, tuple) else (out,)))
            if len(out) != len(nblocks):
                problems.append(
                    f"{k}: {role}[{idx}] index_map returns rank {len(out)} "
                    f"for rank-{len(nblocks)} operand"
                )
                return
            for d, (v, n) in enumerate(zip(out, nblocks)):
                if not (0 <= v < n):
                    problems.append(
                        f"{k}: {role}[{idx}] index_map out of bounds at grid "
                        f"{point}: block index {v} on dim {d} (valid 0..{n - 1})"
                    )
                    return

    for i, (spec, shape) in enumerate(zip(call.in_specs, call.operand_shapes)):
        check_spec(spec, shape, "in_specs", i)
    if len(call.out_specs) != len(call.out_shapes):
        problems.append(
            f"{k}: {len(call.out_specs)} out_specs for "
            f"{len(call.out_shapes)} out_shapes"
        )
    else:
        for i, (spec, (shape, _)) in enumerate(zip(call.out_specs, call.out_shapes)):
            check_spec(spec, shape, "out_specs", i)
    return problems


# --------------------------------------------------------------------------
# kernel registry: tiny synthetic drivers + oracle/test bindings


def _planes(total):
    z = jnp.zeros((total,), jnp.int32)
    return z, z, z, z


def _drive_qf_probe():
    from repro.kernels.qf_probe import qf_probe_tiles

    rem, occ, shf, con = _planes(64)
    fq = jnp.arange(8, dtype=jnp.int32)
    fr = jnp.zeros((8,), jnp.int32)
    qf_probe_tiles(rem, occ, shf, con, fq, fr, tile_t=4, wblk=8, interpret=True)


def _drive_qf_build():
    from repro.kernels.qf_build import qf_build_planes

    pos = jnp.arange(6, dtype=jnp.int32)
    fr = jnp.ones((6,), jnp.int32)
    mb = jnp.zeros((6,), jnp.int32)
    qf_build_planes(pos, fr, mb, total_slots=32, block_s=8, interpret=True)


def _drive_bloom_probe():
    from repro.kernels.bloom_block import bloom_probe_tiles

    cells = jnp.zeros((64,), jnp.int32)
    idx = jnp.sort(
        (jnp.arange(24, dtype=jnp.int32).reshape(8, 3) * 2) % 64, axis=1
    )
    idx = idx[jnp.argsort(jnp.min(idx, axis=1))]
    bloom_probe_tiles(cells, idx, tile_t=4, wblk=8, interpret=True)


def _drive_bloom_count():
    from repro.kernels.bloom_block import bloom_count_tiles

    idx = jnp.sort(jnp.arange(10, dtype=jnp.int32) * 5)
    bloom_count_tiles(idx, ncells=64, block_s=8, interpret=True)


def _drive_cascade_probe():
    from repro.kernels.cascade_probe import cascade_probe_tiles

    planes = [_planes(64), _planes(128)]
    fq0 = jnp.arange(8, dtype=jnp.int32)
    cascade_probe_tiles(
        planes,
        [fq0, fq0 * 2],
        [jnp.zeros((8,), jnp.int32)] * 2,
        tile_t=4,
        wblk=8,
        interpret=True,
    )


def _drive_fuse_probe():
    from repro.kernels.fuse_probe import fuse_probe_tiles

    table = jnp.zeros((64,), jnp.int32)
    p0 = jnp.arange(8, dtype=jnp.int32)
    fuse_probe_tiles(
        table, p0, p0 + 1, p0 + 2, jnp.zeros((8,), jnp.uint32),
        tile_t=4, wblk=8, interpret=True,
    )


@dataclasses.dataclass
class KernelSpec:
    name: str  # kernel module (under repro.kernels)
    entry: str  # public wrapper function
    oracle: str  # bound pure-jnp oracle in repro.kernels.ref
    driver: Callable[[], None]


KERNELS = (
    KernelSpec("qf_probe", "qf_probe_tiles", "probe_ref", _drive_qf_probe),
    KernelSpec("qf_build", "qf_build_planes", "build_ref", _drive_qf_build),
    KernelSpec(
        "bloom_block", "bloom_probe_tiles", "bloom_probe_ref", _drive_bloom_probe
    ),
    KernelSpec(
        "bloom_block", "bloom_count_tiles", "bloom_count_ref", _drive_bloom_count
    ),
    KernelSpec(
        "cascade_probe",
        "cascade_probe_tiles",
        "cascade_probe_ref",
        _drive_cascade_probe,
    ),
    KernelSpec("fuse_probe", "fuse_probe_tiles", "fuse_probe_ref", _drive_fuse_probe),
)


def check_bindings(spec: KernelSpec, tests_dir: str) -> list[str]:
    """The kernel's oracle exists and a parity test references both."""
    from repro.kernels import ref

    problems = []
    if not callable(getattr(ref, spec.oracle, None)):
        problems.append(
            f"{spec.entry}: declared oracle repro.kernels.ref.{spec.oracle} "
            "does not exist"
        )
    seen_entry = seen_oracle = False
    if os.path.isdir(tests_dir):
        for fn in sorted(os.listdir(tests_dir)):
            if not (fn.startswith("test_") and fn.endswith(".py")):
                continue
            with open(os.path.join(tests_dir, fn)) as f:
                text = f.read()
            seen_entry = seen_entry or spec.entry in text
            seen_oracle = seen_oracle or spec.oracle in text
    if not seen_entry:
        problems.append(f"{spec.entry}: no test under tests/ exercises the wrapper")
    if not seen_oracle:
        problems.append(
            f"{spec.entry}: no test under tests/ references oracle {spec.oracle} "
            "(parity test missing)"
        )
    return problems


def run_spec_check(tests_dir: Optional[str] = None, verbose: bool = False) -> int:
    """Drive every registered kernel, validate every captured launch."""
    if tests_dir is None:
        # src/repro/analysis/spec_check.py -> repo root / tests
        here = os.path.dirname(os.path.abspath(__file__))
        root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
        tests_dir = os.path.join(root, "tests")
    problems: list[str] = []
    n_calls = 0
    for spec in KERNELS:
        try:
            calls = capture_kernel_calls(spec.driver)
        except Exception as e:  # noqa: BLE001 - audited + surfaced
            problems.append(
                f"{spec.entry}: driver failed before launch: "
                f"{type(e).__name__}: {e}"
            )
            continue
        if not calls:
            problems.append(f"{spec.entry}: driver captured no pallas_call launch")
        for call in calls:
            n_calls += 1
            ps = validate_call(call)
            problems.extend(ps)
            if verbose:
                status = "FAIL" if ps else "ok"
                problems_note = f" ({len(ps)} problems)" if ps else ""
                print(
                    f"  {spec.entry:24s} {call.kernel_name:20s} grid={call.grid} "
                    f"prefetch={call.num_scalar_prefetch} "
                    f"in={len(call.in_specs)} out={len(call.out_specs)} "
                    f"{status}{problems_note}"
                )
        problems.extend(check_bindings(spec, tests_dir))
    for p in problems:
        print(f"FAIL {p}")
    verdict = "FAILED" if problems else "passed"
    print(
        f"spec-check {verdict}: {len(KERNELS)} kernels, {n_calls} launches "
        f"validated, {len(problems)} problems"
    )
    return 1 if problems else 0
