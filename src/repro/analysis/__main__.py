"""CLI for the static analysis pass: ``python -m repro.analysis``.

Subcommands (default ``all``):

- ``lint``  — AST trace-safety lint over ``src/repro`` against the
  committed ``baseline.toml`` allowlist.
- ``trace`` — jaxpr audit of every registry family's ops against the
  committed ``trace_manifest.json`` (``--update`` refreshes it after a
  reviewed change; ``--strict`` promotes primitive drift to failure).
- ``spec``  — Pallas kernel-contract checker (grid/BlockSpec/
  scalar-prefetch structure + oracle/parity-test bindings).
- ``all``   — run the three in sequence; exit non-zero if any fails.

Exit code 0 = clean against committed baselines; 1 = findings.
"""

from __future__ import annotations

import argparse
import os
import sys


def _repo_root() -> str:
    # src/repro/analysis/__main__.py -> repo root
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def _run_lint(ns) -> int:
    from .lint import load_config, render_report, run_lint

    root = _repo_root()
    result = run_lint(root, load_config(root))
    print(render_report(result, verbose=ns.verbose))
    return 0 if result.ok else 1


def _run_trace(ns) -> int:
    from .trace_audit import run_audit

    return run_audit(update=ns.update, strict=ns.strict, verbose=ns.verbose)


def _run_spec(ns) -> int:
    from .spec_check import run_spec_check

    return run_spec_check(verbose=ns.verbose)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="trace-safety + kernel-contract static analysis pass",
    )
    parser.add_argument("-v", "--verbose", action="store_true")
    sub = parser.add_subparsers(dest="cmd")
    for name in ("lint", "trace", "spec", "all"):
        p = sub.add_parser(name)
        p.add_argument("-v", "--verbose", action="store_true")
        if name in ("trace", "all"):
            p.add_argument("--update", action="store_true",
                           help="refresh the committed trace manifest")
            p.add_argument("--strict", action="store_true",
                           help="primitive-set drift fails instead of noting")
    ns = parser.parse_args(argv)
    cmd = ns.cmd or "all"
    if not hasattr(ns, "update"):
        ns.update, ns.strict = False, False

    if cmd == "lint":
        return _run_lint(ns)
    if cmd == "trace":
        return _run_trace(ns)
    if cmd == "spec":
        return _run_spec(ns)

    rc = 0
    for title, fn in (("repro-lint", _run_lint), ("trace-audit", _run_trace),
                      ("spec-check", _run_spec)):
        print(f"== {title} ==")
        rc = max(rc, fn(ns))
    return rc


if __name__ == "__main__":
    sys.exit(main())
