"""Pallas TPU kernel: bulk quotient-filter membership probe.

The paper's lookup reads one *cluster* — one contiguous region — per
query (its whole point vs. the Bloom filter's k random reads).  The TPU
mapping (DESIGN.md §2): queries are sorted by quotient and tiled; each
program serves T queries from a shared 2*WBLK-slot window of the filter
whose aligned start is scalar-prefetched per tile.  Sorted queries make
neighbouring windows coalesce, so HBM traffic is a linear stream over
the touched region instead of random gathers.

In-window cluster decode is branch-free rank/select arithmetic over a
(T x 2*WBLK) broadcast: anchor = last unshifted slot left of the
quotient, R = occupied count to the bucket, run = R-th run-start after
the anchor (via a shared cumsum), then a remainder compare — the
vectorized form of the paper's Fig. 3 walk.

Queries whose tile span or cluster exceeds the window raise a per-query
overflow flag; the wrapper (ops.py) resolves those on the exact path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def window_decode(w_rem, w_occ, w_shf, w_con, fq, fr, base):
    """Branch-free cluster decode of one query tile against one window.

    ``w_*`` are the (WT,) window planes (rem int32, rest bool), ``fq`` /
    ``fr`` the (T,) tile queries, ``base`` the window's absolute start
    slot.  Returns ``(present, ovf)`` bool (T,) — the vectorized paper
    Fig. 3 walk shared by the single-level and fused-cascade kernels.
    """
    T = fq.shape[0]
    WT = w_rem.shape[0]
    nonempty = w_occ | w_shf

    # shared over the tile: run-start prefix counts
    run_start = (nonempty & ~w_con).astype(jnp.int32)
    cum = jnp.cumsum(run_start.reshape(1, WT), axis=1)[0]  # (WT,)

    rel = fq - base  # (T,) in [0, WT) when tile fits

    js = jax.lax.broadcasted_iota(jnp.int32, (T, WT), 1)
    relc = rel[:, None]

    at_q = js == relc
    occ_q = jnp.any(at_q & w_occ[None, :], axis=1)

    # anchor: largest j <= rel with !is_shifted
    m1 = (~w_shf)[None, :] & (js <= relc)
    b = jnp.max(jnp.where(m1, js, -1), axis=1)  # (T,)
    ovf_left = b < 0

    # R = #occupied buckets in [b, fq]
    R = jnp.sum(
        (w_occ[None, :] & (js >= b[:, None]) & (js <= relc)).astype(jnp.int32),
        axis=1,
    )
    cum_before = jnp.sum(
        jnp.where(js == (b - 1)[:, None], cum[None, :], 0), axis=1
    )  # 0 when b == 0
    C = cum_before + R

    in_run = (cum[None, :] == C[:, None]) & nonempty[None, :]
    present = occ_q & jnp.any(in_run & (w_rem[None, :] == fr[:, None]), axis=1)

    ovf_right = in_run[:, -1]
    ovf_nostart = occ_q & ~ovf_left & (cum[-1] < C)
    ovf = occ_q & (ovf_left | ovf_right | ovf_nostart)
    return present, ovf


def _probe_kernel(
    blk_ref,
    wbase_ref,
    rem_a,
    rem_b,
    occ_a,
    occ_b,
    shf_a,
    shf_b,
    con_a,
    con_b,
    fq_ref,
    fr_ref,
    present_o,
    ovf_o,
):
    t = pl.program_id(0)

    w_rem = jnp.concatenate([rem_a[0, :], rem_b[0, :]])  # (WT,)
    w_occ = jnp.concatenate([occ_a[0, :], occ_b[0, :]]) > 0
    w_shf = jnp.concatenate([shf_a[0, :], shf_b[0, :]]) > 0
    w_con = jnp.concatenate([con_a[0, :], con_b[0, :]]) > 0

    present, ovf = window_decode(
        w_rem, w_occ, w_shf, w_con, fq_ref[0, :], fr_ref[0, :], wbase_ref[t]
    )
    present_o[0, :] = present.astype(jnp.int32)
    ovf_o[0, :] = ovf.astype(jnp.int32)


def qf_probe_tiles(
    rem: jnp.ndarray,
    occ: jnp.ndarray,
    shf: jnp.ndarray,
    con: jnp.ndarray,
    fq_sorted: jnp.ndarray,
    fr_sorted: jnp.ndarray,
    *,
    tile_t: int = 128,
    wblk: int = 1024,
    interpret: bool = True,
):
    """Probe sorted queries. Returns (present, overflow) int32 (B,).

    Planes are int32; fq_sorted must be ascending, padded to a multiple
    of tile_t (duplicate-last padding preserves sortedness).  Tiles
    whose quotient span exceeds the window report overflow for all
    their queries (handled by the caller's exact path).
    """
    total = rem.shape[0]
    B = fq_sorted.shape[0]
    assert B % tile_t == 0
    n_tiles = B // tile_t

    nbw = -(-total // wblk) + 1  # plus one zero (empty-slot) block
    tpad = nbw * wblk

    def pad_plane(x):
        return jnp.concatenate(
            [x.astype(jnp.int32), jnp.zeros((tpad - total,), jnp.int32)]
        ).reshape(nbw, wblk)

    rem2, occ2, shf2, con2 = map(pad_plane, (rem, occ, shf, con))
    fq2 = fq_sorted.reshape(n_tiles, tile_t)
    fr2 = fr_sorted.astype(jnp.int32).reshape(n_tiles, tile_t)

    min_fq = fq2[:, 0]
    max_fq = fq2[:, -1]
    blk = jnp.clip((min_fq - wblk // 4) // wblk, 0, nbw - 2).astype(jnp.int32)
    wbase = blk * wblk
    tile_fits = (max_fq - wbase) < (2 * wblk - wblk // 4)  # room for run tail

    win = lambda off: pl.BlockSpec((1, wblk), lambda t, blk, wbase: (blk[t] + off, 0))
    qspec = pl.BlockSpec((1, tile_t), lambda t, blk, wbase: (t, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles,),
        in_specs=[win(0), win(1)] * 4 + [qspec, qspec],
        out_specs=[qspec, qspec],
    )
    present2, ovf2 = pl.pallas_call(
        _probe_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, tile_t), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, tile_t), jnp.int32),
        ],
        interpret=interpret,
    )(blk, wbase, rem2, rem2, occ2, occ2, shf2, shf2, con2, con2, fq2, fr2)

    ovf2 = ovf2 | (~tile_fits[:, None]).astype(jnp.int32)
    return present2.reshape(B), ovf2.reshape(B)
