"""Pallas TPU kernel: bulk quotient-filter build (slot-plane emit).

The bulk-parallel QF write path (DESIGN.md §2) is: sort fingerprints,
compute probe positions with one cummax scan, then *materialize* the
slot planes — a streaming, bandwidth-bound scatter of n items into
m + slack slots.  This kernel tiles that materialization:

grid = one program per S-slot output tile.  Because probe positions are
strictly increasing, the items landing in an S-slot tile are a
contiguous range of at most S items, whose location is scalar-prefetched
(`blk[t]` = item-block index).  Each program loads two consecutive
S-item blocks (covering any alignment), builds an (2S x S) match matrix
``pos - tile_base == lane`` and reduces it onto the tile — pure VPU
work, no data-dependent control flow, VMEM-resident.

The is_occupied plane is a trivial one-line scatter handled by the
wrapper (ops.py); this kernel emits the payload planes (remainder +
is_shifted/is_continuation), which dominate bytes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _build_kernel(blk_ref, pos_a, pos_b, fr_a, fr_b, mb_a, mb_b, rem_o, meta_o):
    t = pl.program_id(0)
    S = rem_o.shape[1]
    base = t * S

    w_pos = jnp.concatenate([pos_a[0, :], pos_b[0, :]])  # (2S,)
    w_fr = jnp.concatenate([fr_a[0, :], fr_b[0, :]])
    w_mb = jnp.concatenate([mb_a[0, :], mb_b[0, :]])

    rel = w_pos - base  # (2S,) ; outside [0, S) contributes nothing
    cols = jax.lax.broadcasted_iota(jnp.int32, (2 * S, S), 1)
    hit = rel[:, None] == cols  # (2S, S) one-hot by construction

    rem_o[0, :] = jnp.sum(jnp.where(hit, w_fr[:, None], 0), axis=0)
    meta_o[0, :] = jnp.sum(jnp.where(hit, w_mb[:, None], 0), axis=0)


def qf_build_planes(
    pos: jnp.ndarray,
    fr: jnp.ndarray,
    meta_bits: jnp.ndarray,
    total_slots: int,
    *,
    block_s: int = 256,
    interpret: bool = True,
):
    """Scatter items (pos strictly increasing, INT32_MAX padding) into
    (rem, meta) planes of length total_slots.

    meta_bits packs is_continuation | is_shifted << 1 per item.
    """
    S = block_s
    n_tiles = -(-total_slots // S)
    t_pad = n_tiles * S

    # pad item arrays to a whole number of S-blocks plus one sentinel block
    n = pos.shape[0]
    n_blocks = -(-n // S) + 1
    pad = n_blocks * S - n
    pos_p = jnp.concatenate([pos, jnp.full((pad,), jnp.int32(2**31 - 1))])
    fr_p = jnp.concatenate([fr.astype(jnp.int32), jnp.zeros((pad,), jnp.int32)])
    mb_p = jnp.concatenate([meta_bits.astype(jnp.int32), jnp.zeros((pad,), jnp.int32)])
    pos2 = pos_p.reshape(n_blocks, S)
    fr2 = fr_p.reshape(n_blocks, S)
    mb2 = mb_p.reshape(n_blocks, S)

    # scalar prefetch: first item-block feeding each output tile
    starts = jnp.searchsorted(pos_p, jnp.arange(n_tiles, dtype=jnp.int32) * S)
    blk = jnp.minimum(starts // S, n_blocks - 2).astype(jnp.int32)

    win = lambda off: pl.BlockSpec((1, S), lambda t, blk: (blk[t] + off, 0))
    out = pl.BlockSpec((1, S), lambda t, blk: (t, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[win(0), win(1), win(0), win(1), win(0), win(1)],
        out_specs=[out, out],
    )
    rem2, meta2 = pl.pallas_call(
        _build_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, S), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, S), jnp.int32),
        ],
        interpret=interpret,
    )(blk, pos2, pos2, fr2, fr2, mb2, mb2)
    rem = rem2.reshape(t_pad)[:total_slots]
    meta = meta2.reshape(t_pad)[:total_slots]
    return rem, meta
