"""Kernel backend/mode resolution + shared window-prefetch helpers.

Every op in :mod:`repro.kernels.ops` has up to three lowerings:

* ``"mosaic"``    — the Pallas kernel compiled for TPU (``interpret=False``).
* ``"interpret"`` — the same Pallas kernel run by the Pallas interpreter.
  This is a *validation* mode: it executes the exact kernel body
  (one-hot gathers, windowed tiles) but pays a sequential grid loop and
  block-copy overhead, so it is never a production path and benchmarks
  must not present it as one (pre-PR-7 they did, which is where the
  committed "pallas loses to reference by 8x" rows came from).
* ``"xla"``       — a kernel-equivalent jnp lowering: the same algorithm
  (shared decode, window math, exact-fallback semantics) expressed as
  plain XLA ops, minus the hardware tiling that only a real TPU
  rewards.  Bit-identical results to the kernel path.

``resolve`` picks the deployed mode: Mosaic on TPU, the XLA lowering
everywhere else — so ``backend="pallas"`` specs are never slower than
``backend="reference"`` on any platform, which is what the perf gate's
``kernelratio_*`` rows (absolute ceiling 1.10) lock in.  The
``REPRO_KERNEL_MODE`` environment variable forces a mode globally
(tests use it to pin the interpreter); per-call ``mode=``/legacy
``interpret=`` arguments override everything.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

MODES = ("mosaic", "interpret", "xla")

_ENV_VAR = "REPRO_KERNEL_MODE"


def default_mode() -> str:
    """Deployed mode for this process: env override, else by platform."""
    env = os.environ.get(_ENV_VAR)
    if env:
        if env not in MODES:
            raise ValueError(f"{_ENV_VAR} must be one of {MODES}, got {env!r}")
        return env
    return "mosaic" if jax.default_backend() == "tpu" else "xla"


def resolve(mode: str | None = None, interpret: bool | None = None) -> str:
    """Resolve a per-call mode override (``interpret`` is the legacy bool)."""
    if mode is not None:
        if mode not in MODES:
            raise ValueError(f"kernel mode must be one of {MODES}, got {mode!r}")
        return mode
    if interpret is not None:
        return "interpret" if interpret else "mosaic"
    return default_mode()


def is_pallas(mode: str) -> bool:
    """Does this mode execute the Pallas kernel body (vs the jnp lowering)?"""
    return mode in ("mosaic", "interpret")


def pallas_interpret(mode: str) -> bool:
    """The ``interpret=`` kwarg for ``pl.pallas_call`` under this mode."""
    return mode == "interpret"


# ---------------------------------------------------------------------------
# Shared window-prefetch geometry (used by qf_probe / fuse_probe /
# cascade_probe / bloom_block wrappers)
# ---------------------------------------------------------------------------


def sorted_tile_order(sort_key: jnp.ndarray, tile_t: int) -> jnp.ndarray:
    """Permutation gathering queries into ascending ``tile_t``-tiles.

    Pads by duplicating the last (largest) element so sortedness — the
    invariant every window kernel relies on — survives the padding.
    """
    order = jnp.argsort(sort_key)
    pad = (-sort_key.shape[0]) % tile_t
    if pad:
        order = jnp.concatenate([order, jnp.full((pad,), order[-1])])
    return order


def plane_blocks(plane: jnp.ndarray, wblk: int) -> jnp.ndarray:
    """Pad a 1-D plane to ``(nbw, wblk)`` blocks plus one zero block.

    The extra block lets clipped window bases (``blk + 1``) stay in
    range without wrapping into live data.
    """
    total = plane.shape[0]
    nbw = -(-total // wblk) + 1
    pad = nbw * wblk - total
    return jnp.concatenate(
        [plane.astype(jnp.int32), jnp.zeros((pad,), jnp.int32)]
    ).reshape(nbw, wblk)


def window_base(
    min_pos: jnp.ndarray,
    max_pos: jnp.ndarray,
    total: int,
    wblk: int,
    margin: int = 0,
):
    """Per-tile aligned window start + residency check.

    Returns ``(blk, wbase, fits)``: the tile reads blocks ``blk`` and
    ``blk + 1`` (a ``2 * wblk`` window at ``wbase``); ``fits`` is False
    when ``[min_pos - margin, max_pos + margin]`` outruns the window
    (the caller resolves those tiles on its exact path).
    """
    nbw = -(-total // wblk) + 1
    blk = jnp.clip((min_pos - margin) // wblk, 0, nbw - 2).astype(jnp.int32)
    wbase = blk * wblk
    fits = (max_pos - wbase) < (2 * wblk - margin)
    return blk, wbase, fits
