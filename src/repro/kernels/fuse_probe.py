"""Pallas TPU kernel: batched binary-fuse (3-gather) membership probe.

A frozen level answers a query with exactly three cell reads — one per
consecutive segment ``start .. start+2`` — xor'd against the query's
fingerprint.  The TPU mapping mirrors ``qf_probe``: queries are sorted
by their first position and tiled; each program serves T queries from a
shared 2*wblk-cell window of the table whose aligned start is
scalar-prefetched per tile.  Because the three touched segments are
*consecutive*, one window covers all three gathers for every query in
the tile — sorted queries turn the probe into a single linear pass over
the table instead of 3B random gathers.

The gathers themselves are branch-free one-hot contractions (a
(T x window) iota compare per position), the same trick the QF probe
kernel uses for its cluster decode.  Queries whose window residency
fails (tile spans more segments than the window holds) flag overflow
and the wrapper (ops.py) resolves them on the reference path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fuse_probe_kernel(
    blk_ref,
    wbase_ref,
    tab_a,
    tab_b,
    p0_ref,
    p1_ref,
    p2_ref,
    fp_ref,
    hit_o,
):
    t = pl.program_id(0)
    T = p0_ref.shape[1]
    WT = 2 * tab_a.shape[1]

    w = jnp.concatenate([tab_a[0, :], tab_b[0, :]])  # (WT,) int32 cells

    base = wbase_ref[t]
    r0 = p0_ref[0, :] - base  # (T,) window-relative positions
    r1 = p1_ref[0, :] - base
    r2 = p2_ref[0, :] - base

    js = jax.lax.broadcasted_iota(jnp.int32, (T, WT), 1)

    def gather(rel):  # one-hot contraction: w[rel] without dynamic indexing
        return jnp.sum(jnp.where(js == rel[:, None], w[None, :], 0), axis=1)

    got = gather(r0) ^ gather(r1) ^ gather(r2)
    hit_o[0, :] = (got == fp_ref[0, :]).astype(jnp.int32)


def fuse_probe_tiles(
    table: jnp.ndarray,
    p0_sorted: jnp.ndarray,
    p1_sorted: jnp.ndarray,
    p2_sorted: jnp.ndarray,
    fp_sorted: jnp.ndarray,
    *,
    tile_t: int = 128,
    wblk: int = 2048,
    interpret: bool = True,
):
    """Probe position-sorted queries. Returns (hit, overflow) int32 (B,).

    ``table`` is the int32 bit-pattern of the uint32 cell plane;
    ``p0_sorted`` must be ascending and padded to a multiple of
    ``tile_t`` (duplicate-last padding preserves sortedness).  Tiles
    whose third-segment reach exceeds the 2*wblk window report overflow
    for all their queries (resolved by the caller's reference path).
    """
    total = table.shape[0]
    B = p0_sorted.shape[0]
    assert B % tile_t == 0
    n_tiles = B // tile_t

    nbw = -(-total // wblk) + 1  # plus one zero block for clipped windows
    tpad = nbw * wblk
    tab2 = jnp.concatenate(
        [table.astype(jnp.int32), jnp.zeros((tpad - total,), jnp.int32)]
    ).reshape(nbw, wblk)

    p0 = p0_sorted.reshape(n_tiles, tile_t)
    p1 = p1_sorted.reshape(n_tiles, tile_t)
    p2 = p2_sorted.reshape(n_tiles, tile_t)
    fp2 = fp_sorted.astype(jnp.int32).reshape(n_tiles, tile_t)

    blk = jnp.clip(p0[:, 0] // wblk, 0, nbw - 2).astype(jnp.int32)
    wbase = blk * wblk
    # all three positions of every query must land inside [wbase, wbase+2*wblk)
    reach = jnp.maximum(jnp.max(p1, axis=1), jnp.max(p2, axis=1))
    tile_fits = (reach - wbase) < (2 * wblk)

    win = lambda off: pl.BlockSpec((1, wblk), lambda t, blk, wbase: (blk[t] + off, 0))
    qspec = pl.BlockSpec((1, tile_t), lambda t, blk, wbase: (t, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles,),
        in_specs=[win(0), win(1)] + [qspec] * 4,
        out_specs=[qspec],
    )
    (hit2,) = pl.pallas_call(
        _fuse_probe_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_tiles, tile_t), jnp.int32)],
        interpret=interpret,
    )(blk, wbase, tab2, tab2, p0, p1, p2, fp2)

    ovf2 = jnp.broadcast_to((~tile_fits[:, None]).astype(jnp.int32), hit2.shape)
    return hit2.reshape(B), ovf2.reshape(B)
