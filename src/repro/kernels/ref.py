"""Pure-jnp oracles for the Pallas kernels.

Deliberately independent re-implementations (no imports from
``repro.core``) so kernel-vs-ref is a genuine cross-check; tests
additionally compare both against ``repro.core.quotient_filter``.
"""

from __future__ import annotations

import jax.numpy as jnp

INT32_MAX = jnp.int32(2**31 - 1)


def build_ref(total_slots: int, pos, fq, fr, con_bits, shf_bits):
    """Scatter sorted items into slot planes.

    pos: int32 (n,) strictly increasing probe positions (sentinel
    INT32_MAX for padding), fq: bucket ids, fr: remainders (int32
    bit-pattern), con/shf: per-item metadata bits.
    Returns (rem, meta, occ): meta = occ-less packed bits con | shf<<1.
    """
    t = total_slots
    rem = jnp.zeros((t,), jnp.int32).at[pos].set(fr, mode="drop")
    meta = (
        jnp.zeros((t,), jnp.int32)
        .at[pos]
        .set(
            con_bits.astype(jnp.int32) | (shf_bits.astype(jnp.int32) << 1),
            mode="drop",
        )
    )
    occ = jnp.zeros((t,), jnp.int32).at[fq].max(1, mode="drop")
    return rem, meta, occ


def probe_ref(rem, occ, shf, con, fq, fr, window: int):
    """Windowed cluster-decode membership (paper Fig. 3, vectorized).

    rem/occ/shf/con: full slot planes; fq (B,) int32 quotients; fr (B,)
    int32 remainders. Returns (present bool (B,), overflow bool (B,)).
    """
    t = rem.shape[0]
    W = window
    wtot = 2 * W
    js = jnp.arange(wtot, dtype=jnp.int32)
    base = fq - W
    idx = base[:, None] + js[None, :]
    valid = (idx >= 0) & (idx < t)
    idxc = jnp.clip(idx, 0, t - 1)

    w_occ = jnp.where(valid, occ[idxc] > 0, False)
    w_shf = jnp.where(valid, shf[idxc] > 0, False)
    w_con = jnp.where(valid, con[idxc] > 0, False)
    w_rem = jnp.where(valid, rem[idxc], 0)
    nonempty = w_occ | w_shf

    occ_q = w_occ[:, W]
    cand = jnp.where((~w_shf) & (js <= W)[None, :], js[None, :], -1)
    b = jnp.max(cand, axis=1)
    ovf_left = b < 0

    sel = w_occ & (js[None, :] >= b[:, None]) & (js <= W)[None, :]
    R = jnp.sum(sel, axis=1, dtype=jnp.int32)

    run_start = nonempty & ~w_con
    cum = jnp.cumsum(run_start.astype(jnp.int32), axis=1)
    cum_before = jnp.where(
        b > 0,
        jnp.take_along_axis(cum, jnp.maximum(b - 1, 0)[:, None], axis=1)[:, 0],
        0,
    )
    C = cum_before + R

    in_run = (cum == C[:, None]) & nonempty
    present = occ_q & jnp.any(in_run & (w_rem == fr[:, None]), axis=1)
    ovf_right = in_run[:, -1]
    ovf_nostart = occ_q & ~ovf_left & (cum[:, -1] < C)
    overflow = occ_q & (ovf_left | ovf_right | ovf_nostart)
    return present, overflow


def fuse_probe_ref(table, p0, p1, p2, fp):
    """Binary-fuse membership oracle: three gathers + xor + compare.

    table: uint32 (slots,) fingerprint cells; p0/p1/p2: int32 (B,) cell
    positions (already hashed — one per consecutive segment); fp: uint32
    (B,) stored fingerprints.  Returns present bool (B,).  The caller
    owns the empty-table (n == 0) guard.
    """
    return (table[p0] ^ table[p1] ^ table[p2]) == fp


def bloom_probe_ref(cells, idx):
    """Blocked-Bloom membership oracle: AND of k direct gathers.

    cells: int32 (ncells,) cell plane; idx: int32 (B, k) cell indices.
    Returns present bool (B,).
    """
    return jnp.all(cells[idx] > 0, axis=1)


def bloom_count_ref(idx_flat, ncells: int):
    """Per-cell increment counts from flat cell indices.

    Sentinel / out-of-range indices (e.g. INT32_MAX for masked keys)
    contribute nothing.  Returns int32 (ncells,).
    """
    return jnp.zeros((ncells,), jnp.int32).at[idx_flat].add(1, mode="drop")


def cascade_probe_ref(level_planes, fq_levels, fr_levels, window: int):
    """Multi-level cascade probe oracle: per-level windowed decode
    composed into (hit, ovf) int32 bitmasks (bit l = level l), matching
    the fused kernel's output contract.
    """
    B = fq_levels[0].shape[0]
    hit = jnp.zeros((B,), jnp.int32)
    ovf = jnp.zeros((B,), jnp.int32)
    for lvl, (rem, occ, shf, con) in enumerate(level_planes):
        p, o = probe_ref(rem, occ, shf, con, fq_levels[lvl], fr_levels[lvl], window)
        hit = hit | (p.astype(jnp.int32) << lvl)
        ovf = ovf | (o.astype(jnp.int32) << lvl)
    return hit, ovf
