"""Pallas TPU kernel: fused multi-level cascade membership probe.

The cascade answers a query by probing Q0 plus every disk level — the
reference path launches one windowed probe per level, re-reading the
sorted query tiles L times and paying L grid launches.  This kernel
fuses the whole unfrozen stack into ONE grid over the sorted queries:

* Requotienting is monotone, so sorting queries once by their p-bit
  canonical fingerprint sorts them by *every* level's quotient
  simultaneously — one sort serves all levels.
* The grid is one program per query tile.  For each of the L levels the
  program sees that level's own 2*wblk-slot window (aligned start
  scalar-prefetched per (tile, level), exactly ``qf_probe``'s
  two-consecutive-block scheme, just L of them), and runs the shared
  branch-free cluster decode (``qf_probe.window_decode``) per level.
* Per-query results come back as two int32 *bitmasks* (hit / overflow,
  bit l = level l), so the launch has a fixed two-output shape for any
  static depth L.

Frozen (binary-fuse) levels cannot join the fused grid — their probe
positions are hashes of the fingerprint, not monotone in it, so they
need their own position sort — and are folded in by the wrapper
(``ops.cascade_lookup``) via the existing 3-gather ``fuse_probe`` pass.

Tiles whose quotient span outruns a level's window flag that level's
overflow bit; the wrapper resolves flagged queries on the exact path,
per level, preserving bit-exactness with the per-level reference.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import dispatch
from .qf_probe import window_decode


def _make_kernel(L: int):
    """Kernel body for a static stack depth L.

    Ref layout (positional): 2L scalar-prefetch refs (blk_l, wbase_l
    interleaved), then 8 window refs per level (rem/occ/shf/con, two
    consecutive blocks each), then fq/fr query tiles per level, then
    the two bitmask outputs.
    """

    def kernel(*refs):
        scalars = refs[: 2 * L]
        planes = refs[2 * L : 10 * L]
        queries = refs[10 * L : 12 * L]
        hit_o, ovf_o = refs[12 * L], refs[12 * L + 1]
        t = pl.program_id(0)

        T = queries[0].shape[1]
        hitm = jnp.zeros((T,), jnp.int32)
        ovfm = jnp.zeros((T,), jnp.int32)
        for lvl in range(L):
            rem_a, rem_b, occ_a, occ_b, shf_a, shf_b, con_a, con_b = planes[
                8 * lvl : 8 * (lvl + 1)
            ]
            w_rem = jnp.concatenate([rem_a[0, :], rem_b[0, :]])
            w_occ = jnp.concatenate([occ_a[0, :], occ_b[0, :]]) > 0
            w_shf = jnp.concatenate([shf_a[0, :], shf_b[0, :]]) > 0
            w_con = jnp.concatenate([con_a[0, :], con_b[0, :]]) > 0
            present, ovf = window_decode(
                w_rem,
                w_occ,
                w_shf,
                w_con,
                queries[2 * lvl][0, :],
                queries[2 * lvl + 1][0, :],
                scalars[2 * lvl + 1][t],
            )
            hitm = hitm | (present.astype(jnp.int32) << lvl)
            ovfm = ovfm | (ovf.astype(jnp.int32) << lvl)
        hit_o[0, :] = hitm
        ovf_o[0, :] = ovfm

    return kernel


def cascade_probe_tiles(
    level_planes,
    fq_levels,
    fr_levels,
    *,
    tile_t: int = 128,
    wblk: int = 1024,
    interpret: bool = True,
):
    """Probe all QF levels of a cascade in one fused grid.

    ``level_planes`` is a list of ``(rem, occ, shf, con)`` int32 plane
    tuples (one per level, arbitrary per-level sizes); ``fq_levels`` /
    ``fr_levels`` hold each level's quotient/remainder view of the SAME
    canonically sorted, tile-padded query batch (so every ``fq_levels[l]``
    is ascending).  Returns ``(hit_mask, ovf_mask)`` int32 (B,) bitmask
    arrays — bit ``l`` of query ``i`` is level ``l``'s verdict/overflow.
    """
    L = len(level_planes)
    if L < 1:
        raise ValueError("cascade_probe_tiles needs at least one level")
    B = fq_levels[0].shape[0]
    assert B % tile_t == 0
    n_tiles = B // tile_t

    scalars = []
    plane_args = []
    in_specs = []
    tile_mask = jnp.zeros((n_tiles,), jnp.int32)

    def win(lvl, off):
        # index_map sees (t, s_0 .. s_{2L-1}); blk of level l is s[2l]
        return pl.BlockSpec(
            (1, wblk), lambda t, *s, lvl=lvl, off=off: (s[2 * lvl][t] + off, 0)
        )

    qspec = pl.BlockSpec((1, tile_t), lambda t, *s: (t, 0))

    for lvl, (rem, occ, shf, con) in enumerate(level_planes):
        total = rem.shape[0]
        fq2 = fq_levels[lvl].reshape(n_tiles, tile_t)
        blk, wbase, fits = dispatch.window_base(
            fq2[:, 0], fq2[:, -1], total, wblk, margin=wblk // 4
        )
        scalars += [blk, wbase]
        tile_mask = tile_mask | ((~fits).astype(jnp.int32) << lvl)
        for plane in (rem, occ, shf, con):
            padded = dispatch.plane_blocks(plane, wblk)
            plane_args += [padded, padded]
            in_specs += [win(lvl, 0), win(lvl, 1)]

    query_args = []
    for lvl in range(L):
        query_args += [
            fq_levels[lvl].reshape(n_tiles, tile_t),
            fr_levels[lvl].astype(jnp.int32).reshape(n_tiles, tile_t),
        ]
        in_specs += [qspec, qspec]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2 * L,
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=[qspec, qspec],
    )
    hit2, ovf2 = pl.pallas_call(
        _make_kernel(L),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, tile_t), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles, tile_t), jnp.int32),
        ],
        interpret=interpret,
    )(*scalars, *plane_args, *query_args)

    ovf2 = ovf2 | tile_mask[:, None]
    return hit2.reshape(B), ovf2.reshape(B)
