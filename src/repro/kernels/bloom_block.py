"""Pallas TPU kernels: blocked-Bloom bin insert + contains.

The blocked Bloom filter's whole design point is that all k probes of a
key land inside one ``block_bits``-sized bin (one cache line / flash
page) — the layout of SNIPPETS.md's BlockBloomFilter (64-byte bins) and
the paper's buffered Bloom variant.  That locality is exactly what the
window-prefetch scheme rewards:

* **contains** — queries sorted by bin share a 2*wblk-cell window whose
  aligned start is scalar-prefetched per tile; each of the k probes is
  a branch-free one-hot gather in the window, AND-reduced.  Tiles whose
  bins outrun the window flag overflow (wrapper resolves exactly).
* **insert** — the write side mirrors ``qf_build``: ALL k*B touched
  cell indices are sorted, so the items landing in an S-cell output
  tile are one contiguous range whose item-block is scalar-prefetched;
  the kernel reduces a (2S x S) one-hot match matrix into per-cell hit
  COUNTS.  Counts compose with any cell plane: ``cells + counts``
  (counting), ``cells | (counts > 0)`` (plain bits), ``cells - counts``
  (counting delete) — and because the aggregation is commutative, tiles
  whose bins are denser than the item window simply fall back to a
  scatter recount without affecting the rest (see ``ops.bloom_counts``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import dispatch


def _make_probe_kernel(k: int):
    def kernel(*refs):
        # refs: blk, wbase, cell_a, cell_b, idx_0 .. idx_{k-1}, hit_o
        blk_ref, wbase_ref, cell_a, cell_b = refs[:4]
        idx_refs = refs[4 : 4 + k]
        hit_o = refs[4 + k]
        t = pl.program_id(0)

        T = idx_refs[0].shape[1]
        WT = 2 * cell_a.shape[1]
        w = jnp.concatenate([cell_a[0, :], cell_b[0, :]])  # (WT,) cells
        base = wbase_ref[t]
        js = jax.lax.broadcasted_iota(jnp.int32, (T, WT), 1)

        hit = jnp.ones((T,), jnp.bool_)
        for j in range(k):
            rel = idx_refs[j][0, :] - base
            val = jnp.sum(jnp.where(js == rel[:, None], w[None, :], 0), axis=1)
            hit = hit & (val > 0)
        hit_o[0, :] = hit.astype(jnp.int32)

    return kernel


def bloom_probe_tiles(
    cells: jnp.ndarray,
    idx_sorted: jnp.ndarray,
    *,
    tile_t: int = 128,
    wblk: int = 4096,
    interpret: bool = True,
):
    """AND-of-k probe of bin-sorted queries. Returns (hit, ovf) int32 (B,).

    ``cells`` is the int32 cell plane; ``idx_sorted`` is (B, k) cell
    indices with rows ordered by their minimum index (bin order) and B a
    multiple of ``tile_t``.  Tiles whose index span exceeds the 2*wblk
    window report overflow for all their queries.
    """
    total = cells.shape[0]
    B, k = idx_sorted.shape
    assert B % tile_t == 0
    n_tiles = B // tile_t

    cells2 = dispatch.plane_blocks(cells, wblk)
    idx3 = idx_sorted.reshape(n_tiles, tile_t, k)
    mn = jnp.min(idx3, axis=(1, 2))
    mx = jnp.max(idx3, axis=(1, 2))
    blk, wbase, fits = dispatch.window_base(mn, mx, total, wblk)

    win = lambda off: pl.BlockSpec((1, wblk), lambda t, blk, wbase: (blk[t] + off, 0))
    qspec = pl.BlockSpec((1, tile_t), lambda t, blk, wbase: (t, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles,),
        in_specs=[win(0), win(1)] + [qspec] * k,
        out_specs=[qspec],
    )
    idx_args = [
        idx3[:, :, j].reshape(n_tiles, tile_t) for j in range(k)
    ]
    (hit2,) = pl.pallas_call(
        _make_probe_kernel(k),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_tiles, tile_t), jnp.int32)],
        interpret=interpret,
    )(blk, wbase, cells2, cells2, *idx_args)

    ovf2 = jnp.broadcast_to((~fits[:, None]).astype(jnp.int32), hit2.shape)
    return hit2.reshape(B), ovf2.reshape(B)


def _count_kernel(blk_ref, idx_a, idx_b, cnt_o):
    t = pl.program_id(0)
    S = cnt_o.shape[1]
    base = t * S

    w_idx = jnp.concatenate([idx_a[0, :], idx_b[0, :]])  # (2S,)
    rel = w_idx - base  # outside [0, S) contributes nothing
    cols = jax.lax.broadcasted_iota(jnp.int32, (2 * S, S), 1)
    hit = rel[:, None] == cols  # (2S, S)
    cnt_o[0, :] = jnp.sum(hit.astype(jnp.int32), axis=0)


def bloom_count_tiles(
    idx_flat_sorted: jnp.ndarray,
    ncells: int,
    *,
    block_s: int = 512,
    interpret: bool = True,
):
    """Aggregate ascending cell indices into per-cell counts, tiled.

    Returns ``(counts, fits)``: counts is int32 (n_tiles * block_s,)
    (slice to ``ncells``); ``fits`` is bool (n_tiles,), False where a
    tile's item range exceeded its two prefetched item blocks (denser
    than 2*block_s items — the caller recounts those tiles by scatter).
    Sentinel indices (>= n_tiles * block_s, e.g. INT32_MAX for masked
    keys) never land in any tile.
    """
    S = block_s
    n_tiles = -(-ncells // S)
    n = idx_flat_sorted.shape[0]
    n_blocks = -(-n // S) + 1
    pad = n_blocks * S - n
    idx_p = jnp.concatenate(
        [idx_flat_sorted, jnp.full((pad,), jnp.int32(2**31 - 1))]
    )
    idx2 = idx_p.reshape(n_blocks, S)

    tile_base = jnp.arange(n_tiles, dtype=jnp.int32) * S
    starts = jnp.searchsorted(idx_p, tile_base)
    ends = jnp.searchsorted(idx_p, tile_base + S)
    blk = jnp.minimum(starts // S, n_blocks - 2).astype(jnp.int32)
    fits = ends <= (blk + 2) * S

    win = lambda off: pl.BlockSpec((1, S), lambda t, blk: (blk[t] + off, 0))
    out = pl.BlockSpec((1, S), lambda t, blk: (t, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[win(0), win(1)],
        out_specs=[out],
    )
    (cnt2,) = pl.pallas_call(
        _count_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_tiles, S), jnp.int32)],
        interpret=interpret,
    )(blk, idx2, idx2)
    return cnt2.reshape(n_tiles * S), fits
