"""jit'd wrappers binding the Pallas kernels to the core QF state.

``interpret=True`` (default here) runs the kernel bodies in Python on
CPU — the validation mode for this container; on real TPUs the same
calls compile via Mosaic (`interpret=False`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fuse_filter as ffc
from repro.core import quotient_filter as qf
from .fuse_probe import fuse_probe_tiles
from .qf_build import qf_build_planes
from .qf_probe import qf_probe_tiles

INT32_MAX = jnp.int32(2**31 - 1)


@functools.partial(
    jax.jit, static_argnums=(0,), static_argnames=("interpret", "block_s")
)
def build_sorted(
    cfg: qf.QFConfig,
    fq: jnp.ndarray,
    fr: jnp.ndarray,
    n,
    *,
    interpret: bool = True,
    block_s: int = 256,
) -> qf.QFState:
    """Kernel-backed equivalent of ``quotient_filter.build_sorted``.

    Probe positions and metadata bits are one cheap scan in jnp; the
    bandwidth-bound plane materialization runs in the Pallas kernel.
    """
    if cfg.r > 31:
        raise ValueError("kernel path packs remainders in int32 lanes (r <= 31)")
    t = cfg.total_slots
    nn = jnp.asarray(n, jnp.int32)
    idx = jnp.arange(fq.shape[0], dtype=jnp.int32)
    valid = idx < nn

    # sentinel stays out of the subtraction (-INT32_MAX - idx wraps for idx >= 2)
    pos = idx + jax.lax.cummax(jnp.where(valid, fq - idx, -INT32_MAX))
    overflow = jnp.any(valid & (pos >= t))
    spos = jnp.where(valid, pos, INT32_MAX)
    con_b = valid & (idx > 0) & (fq == jnp.roll(fq, 1))
    shf_b = valid & (pos != fq)
    meta_bits = con_b.astype(jnp.int32) | (shf_b.astype(jnp.int32) << 1)

    rem_i32, meta = qf_build_planes(
        spos, fr, meta_bits, t, block_s=block_s, interpret=interpret
    )
    occ = (
        jnp.zeros((t,), jnp.bool_)
        .at[jnp.where(valid, fq, INT32_MAX)]
        .set(True, mode="drop")
    )
    return qf.QFState(
        rem=rem_i32.astype(jnp.uint32),
        occ=occ,
        shf=(meta >> 1) > 0,
        con=(meta & 1) > 0,
        n=nn,
        overflow=overflow,
    )


@functools.partial(
    jax.jit, static_argnums=(0,), static_argnames=("interpret", "tile_t", "wblk")
)
def lookup(
    cfg: qf.QFConfig,
    state: qf.QFState,
    fq: jnp.ndarray,
    fr: jnp.ndarray,
    *,
    interpret: bool = True,
    tile_t: int = 128,
    wblk: int = 1024,
):
    """Kernel-backed MAY-CONTAIN; overflows resolve on the exact path."""
    B0 = fq.shape[0]
    order = jnp.argsort(fq)
    pad = (-B0) % tile_t
    osort = jnp.concatenate([order, jnp.full((pad,), order[-1])]) if pad else order
    fq_s = fq[osort]
    fr_s = fr[osort]

    present_s, ovf_s = qf_probe_tiles(
        state.rem.astype(jnp.int32),
        state.occ.astype(jnp.int32),
        state.shf.astype(jnp.int32),
        state.con.astype(jnp.int32),
        fq_s,
        fr_s,
        tile_t=tile_t,
        wblk=wblk,
        interpret=interpret,
    )
    # un-permute (padding wrote duplicates of a real slot; last write wins
    # with identical values, so it is harmless)
    present = jnp.zeros((B0,), jnp.int32).at[osort].set(present_s, mode="drop")
    ovf = jnp.zeros((B0,), jnp.int32).at[osort].max(ovf_s, mode="drop")

    def resolve(args):
        present, ovf = args
        exact = qf.lookup_exact(cfg, state, fq, fr)
        return jnp.where(ovf > 0, exact, present > 0)

    return jax.lax.cond(
        jnp.any(ovf > 0), resolve, lambda a: a[0] > 0, (present, ovf)
    )


def contains(cfg: qf.QFConfig, state: qf.QFState, keys: jnp.ndarray, **kw):
    fq, fr = qf.fingerprints(cfg, keys)
    return lookup(cfg, state, fq, fr, **kw)


@functools.partial(
    jax.jit, static_argnums=(0,), static_argnames=("interpret", "tile_t", "wblk")
)
def fuse_lookup(
    cfg: ffc.FuseConfig,
    state: ffc.FuseState,
    fq: jnp.ndarray,
    fr: jnp.ndarray,
    *,
    interpret: bool = True,
    tile_t: int = 128,
    wblk: int = 2048,
):
    """Kernel-backed binary-fuse MAY-CONTAIN for canonical fingerprints.

    Sorts queries by first position so tile windows stream the table;
    tiles that outrun their window fall back to the reference 3-gather.
    """
    p0, p1, p2, fp = ffc.fuse_hash(cfg, fq, fr, state.fuse_seed)
    B0 = p0.shape[0]
    order = jnp.argsort(p0)
    pad = (-B0) % tile_t
    osort = jnp.concatenate([order, jnp.full((pad,), order[-1])]) if pad else order

    hit_s, ovf_s = fuse_probe_tiles(
        state.table.astype(jnp.int32),
        p0[osort],
        p1[osort],
        p2[osort],
        fp[osort],
        tile_t=tile_t,
        wblk=wblk,
        interpret=interpret,
    )
    hit = jnp.zeros((B0,), jnp.int32).at[osort].set(hit_s, mode="drop")
    ovf = jnp.zeros((B0,), jnp.int32).at[osort].max(ovf_s, mode="drop")

    def resolve(args):
        hit, ovf = args
        exact = (state.table[p0] ^ state.table[p1] ^ state.table[p2]) == fp
        return jnp.where(ovf > 0, exact, hit > 0)

    present = jax.lax.cond(
        jnp.any(ovf > 0), resolve, lambda a: a[0] > 0, (hit, ovf)
    )
    return (state.n > 0) & present


def fuse_contains(cfg: ffc.FuseConfig, state: ffc.FuseState, keys: jnp.ndarray, **kw):
    fq, fr = ffc.key_fingerprints(cfg, keys)
    return fuse_lookup(cfg, state, fq, fr, **kw)


@functools.partial(jax.jit, static_argnums=(0,))
def build_chunk(
    cfg: qf.QFConfig,
    state: qf.QFState,
    fq: jnp.ndarray,
    fr: jnp.ndarray,
    k,
    last_pos,
    last_fq,
):
    """Chunked build-plane entry: append one bounded sorted chunk to a
    partially built QF (the incremental-resize migration step).

    ``state`` must hold exactly the entries appended so far, built in
    sorted fingerprint order; ``(last_pos, last_fq)`` carry the probe
    scan across chunk boundaries (both -1 before the first chunk).  The
    first ``k`` rows of ``(fq, fr)`` are valid and sorted, and every
    fingerprint sorts at-or-after the carried ``last_fq``.  Appending
    chunk by chunk reproduces ``build_sorted`` of the full prefix
    bit-for-bit: the probe recurrence ``pos[i] = max(pos[i-1] + 1,
    fq[i])`` closed-forms to ``i + max(last_pos + 1, cummax(fq - i))``,
    so positions strictly increase and chunks never overwrite.

    O(chunk) work: unlike the full builds this is a handful of
    scattered single-slot writes, not a tiled streaming pass, so there
    is no Pallas variant — the bandwidth-bound full rebuilds around a
    migration (begin/finish) route through ``build_sorted`` above.

    Returns ``(state, last_pos, last_fq)`` with the carries advanced.
    """
    t = cfg.total_slots
    kk = jnp.asarray(k, jnp.int32)
    idx = jnp.arange(fq.shape[0], dtype=jnp.int32)
    valid = idx < kk

    d = jnp.where(valid, fq - idx, -INT32_MAX)
    pos = idx + jnp.maximum(last_pos + 1, jax.lax.cummax(d))
    overflow = state.overflow | jnp.any(valid & (pos >= t))
    spos = jnp.where(valid, pos, INT32_MAX)

    prev_fq = jnp.roll(fq, 1).at[0].set(last_fq)
    con_bits = valid & (fq == prev_fq)
    shf_bits = valid & (pos != fq)

    new = qf.QFState(
        rem=state.rem.at[spos].set(fr, mode="drop"),
        occ=state.occ.at[jnp.where(valid, fq, INT32_MAX)].set(True, mode="drop"),
        shf=state.shf.at[spos].set(shf_bits, mode="drop"),
        con=state.con.at[spos].set(con_bits, mode="drop"),
        n=state.n + kk,
        overflow=overflow,
    )
    last = jnp.clip(kk - 1, 0, fq.shape[0] - 1)
    new_last_pos = jnp.where(kk > 0, pos[last], last_pos)
    new_last_fq = jnp.where(kk > 0, fq[last], last_fq)
    return new, new_last_pos, new_last_fq
