"""jit'd wrappers binding the Pallas kernels to the core filter states.

Every op dispatches on a kernel *mode* (see :mod:`.dispatch`):

* ``"mosaic"``    — compiled Pallas kernel (TPU).
* ``"interpret"`` — Pallas interpreter; validation only.
* ``"xla"``       — bit-exact kernel-equivalent jnp lowering; the
  deployed path on CPU/GPU, where interpret-mode tiling would only add
  overhead.

``mode=None`` auto-selects (Mosaic on TPU, XLA elsewhere,
``REPRO_KERNEL_MODE`` env override); the legacy ``interpret=`` bool is
still honored (True -> "interpret", False -> "mosaic").  All three
modes return identical results — parity is enforced by
``tests/test_kernels.py`` and the perf gate's ``kernelratio_*`` rows
keep the deployed mode at-or-under the reference cost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fuse_filter as ffc
from repro.core import quotient_filter as qf
from . import dispatch
from .bloom_block import bloom_count_tiles, bloom_probe_tiles
from .cascade_probe import cascade_probe_tiles
from .fuse_probe import fuse_probe_tiles
from .qf_build import qf_build_planes
from .qf_probe import qf_probe_tiles

INT32_MAX = jnp.int32(2**31 - 1)


# ---------------------------------------------------------------------------
# QF bulk build
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnums=(0,), static_argnames=("mode", "block_s")
)
def _build_sorted(cfg, fq, fr, n, *, mode, block_s):
    if dispatch.is_pallas(mode):
        t = cfg.total_slots
        nn = jnp.asarray(n, jnp.int32)
        idx = jnp.arange(fq.shape[0], dtype=jnp.int32)
        valid = idx < nn

        # sentinel stays out of the subtraction (-INT32_MAX - idx wraps
        # for idx >= 2)
        pos = idx + jax.lax.cummax(jnp.where(valid, fq - idx, -INT32_MAX))
        overflow = jnp.any(valid & (pos >= t))
        spos = jnp.where(valid, pos, INT32_MAX)
        con_b = valid & (idx > 0) & (fq == jnp.roll(fq, 1))
        shf_b = valid & (pos != fq)
        meta_bits = con_b.astype(jnp.int32) | (shf_b.astype(jnp.int32) << 1)

        rem_i32, meta = qf_build_planes(
            spos,
            fr,
            meta_bits,
            t,
            block_s=block_s,
            interpret=dispatch.pallas_interpret(mode),
        )
        occ = (
            jnp.zeros((t,), jnp.bool_)
            .at[jnp.where(valid, fq, INT32_MAX)]
            .set(True, mode="drop")
        )
        return qf.QFState(
            rem=rem_i32.astype(jnp.uint32),
            occ=occ,
            shf=(meta >> 1) > 0,
            con=(meta & 1) > 0,
            n=nn,
            overflow=overflow,
        )
    # xla mode: the reference scatter IS the kernel-equivalent lowering
    # (same closed-form positions, plane-at-a-time writes)
    return qf.build_sorted(cfg, fq, fr, n)


def build_sorted(
    cfg: qf.QFConfig,
    fq: jnp.ndarray,
    fr: jnp.ndarray,
    n,
    *,
    mode: str | None = None,
    interpret: bool | None = None,
    block_s: int = 256,
) -> qf.QFState:
    """Mode-dispatched equivalent of ``quotient_filter.build_sorted``.

    Probe positions and metadata bits are one cheap scan in jnp; the
    bandwidth-bound plane materialization runs in the Pallas kernel
    (pallas modes) or as the reference jnp scatter (xla mode).
    """
    if cfg.r > 31:
        raise ValueError("kernel path packs remainders in int32 lanes (r <= 31)")
    return _build_sorted(
        cfg, fq, fr, n, mode=dispatch.resolve(mode, interpret), block_s=block_s
    )


# ---------------------------------------------------------------------------
# QF bulk probe
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnums=(0,), static_argnames=("mode", "tile_t", "wblk")
)
def _lookup(cfg, state, fq, fr, *, mode, tile_t, wblk):
    if not dispatch.is_pallas(mode):
        # xla mode: decode the table once, binary-search the batch —
        # O(m + B log m) vs the reference's O(B * window) per-query
        # cluster decode; same exact-membership answer
        return qf.lookup_exact(cfg, state, fq, fr)

    B0 = fq.shape[0]
    osort = dispatch.sorted_tile_order(fq, tile_t)
    fq_s = fq[osort]
    fr_s = fr[osort]

    present_s, ovf_s = qf_probe_tiles(
        state.rem.astype(jnp.int32),
        state.occ.astype(jnp.int32),
        state.shf.astype(jnp.int32),
        state.con.astype(jnp.int32),
        fq_s,
        fr_s,
        tile_t=tile_t,
        wblk=wblk,
        interpret=dispatch.pallas_interpret(mode),
    )
    # un-permute (padding wrote duplicates of a real slot; last write wins
    # with identical values, so it is harmless)
    present = jnp.zeros((B0,), jnp.int32).at[osort].set(present_s, mode="drop")
    ovf = jnp.zeros((B0,), jnp.int32).at[osort].max(ovf_s, mode="drop")

    def resolve(args):
        present, ovf = args
        exact = qf.lookup_exact(cfg, state, fq, fr)
        return jnp.where(ovf > 0, exact, present > 0)

    return jax.lax.cond(
        jnp.any(ovf > 0), resolve, lambda a: a[0] > 0, (present, ovf)
    )


def lookup(
    cfg: qf.QFConfig,
    state: qf.QFState,
    fq: jnp.ndarray,
    fr: jnp.ndarray,
    *,
    mode: str | None = None,
    interpret: bool | None = None,
    tile_t: int = 128,
    wblk: int = 1024,
):
    """Mode-dispatched MAY-CONTAIN; overflows resolve on the exact path."""
    return _lookup(
        cfg,
        state,
        fq,
        fr,
        mode=dispatch.resolve(mode, interpret),
        tile_t=tile_t,
        wblk=wblk,
    )


def contains(cfg: qf.QFConfig, state: qf.QFState, keys: jnp.ndarray, **kw):
    fq, fr = qf.fingerprints(cfg, keys)
    return lookup(cfg, state, fq, fr, **kw)


# ---------------------------------------------------------------------------
# Binary-fuse (3-gather) probe
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnums=(0,), static_argnames=("mode", "tile_t", "wblk")
)
def _fuse_lookup(cfg, state, fq, fr, *, mode, tile_t, wblk):
    p0, p1, p2, fp = ffc.fuse_hash(cfg, fq, fr, state.fuse_seed)
    if not dispatch.is_pallas(mode):
        # xla mode: the 3-gather is already one contiguous-window read
        # per segment triple — gather directly
        present = (state.table[p0] ^ state.table[p1] ^ state.table[p2]) == fp
        return (state.n > 0) & present

    B0 = p0.shape[0]
    osort = dispatch.sorted_tile_order(p0, tile_t)

    hit_s, ovf_s = fuse_probe_tiles(
        state.table.astype(jnp.int32),
        p0[osort],
        p1[osort],
        p2[osort],
        fp[osort],
        tile_t=tile_t,
        wblk=wblk,
        interpret=dispatch.pallas_interpret(mode),
    )
    hit = jnp.zeros((B0,), jnp.int32).at[osort].set(hit_s, mode="drop")
    ovf = jnp.zeros((B0,), jnp.int32).at[osort].max(ovf_s, mode="drop")

    def resolve(args):
        hit, ovf = args
        exact = (state.table[p0] ^ state.table[p1] ^ state.table[p2]) == fp
        return jnp.where(ovf > 0, exact, hit > 0)

    present = jax.lax.cond(
        jnp.any(ovf > 0), resolve, lambda a: a[0] > 0, (hit, ovf)
    )
    return (state.n > 0) & present


def fuse_lookup(
    cfg: ffc.FuseConfig,
    state: ffc.FuseState,
    fq: jnp.ndarray,
    fr: jnp.ndarray,
    *,
    mode: str | None = None,
    interpret: bool | None = None,
    tile_t: int = 128,
    wblk: int = 2048,
):
    """Mode-dispatched binary-fuse MAY-CONTAIN for canonical fingerprints.

    Pallas modes sort queries by first position so tile windows stream
    the table; tiles that outrun their window fall back to the reference
    3-gather.  XLA mode gathers directly.
    """
    return _fuse_lookup(
        cfg,
        state,
        fq,
        fr,
        mode=dispatch.resolve(mode, interpret),
        tile_t=tile_t,
        wblk=wblk,
    )


@functools.partial(
    jax.jit, static_argnums=(0,), static_argnames=("mode", "tile_t", "wblk")
)
def _fuse_contains_impl(cfg, state, keys, *, mode, tile_t, wblk):
    fq, fr = ffc.key_fingerprints(cfg, keys)
    return _fuse_lookup(cfg, state, fq, fr, mode=mode, tile_t=tile_t, wblk=wblk)


def fuse_contains(
    cfg: ffc.FuseConfig,
    state: ffc.FuseState,
    keys: jnp.ndarray,
    *,
    mode: str | None = None,
    interpret: bool | None = None,
    tile_t: int = 128,
    wblk: int = 2048,
):
    """Key-level fuse probe: hash + lookup under ONE jitted program (the
    ~30-op fingerprint hash costs milliseconds dispatched eagerly)."""
    return _fuse_contains_impl(
        cfg,
        state,
        keys,
        mode=dispatch.resolve(mode, interpret),
        tile_t=tile_t,
        wblk=wblk,
    )


# ---------------------------------------------------------------------------
# Fused multi-level cascade probe
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnums=(0, 1),
    static_argnames=("mode", "tile_t", "wblk"),
)
def _cascade_lookup(
    qf_cfgs, fuse_cfgs, qf_states, fuse_states, keys, *, mode, tile_t, wblk
):
    p = qf_cfgs[0].q + qf_cfgs[0].r
    seed = qf_cfgs[0].seed
    for c in qf_cfgs:
        if c.q + c.r != p or c.seed != seed:
            raise ValueError("cascade levels must share fingerprint bits and seed")
    for c in fuse_cfgs:
        if c.p != p or c.seed != seed:
            raise ValueError("frozen levels must share fingerprint bits and seed")

    # hash ONCE in the canonical split; every level's (fq, fr) view is a
    # bit re-split of the same p-bit fingerprint (requotient), so the
    # fused path never re-hashes per level the way the reference does
    qc, rc = ffc.canonical_split(p)
    canon = qf.QFConfig(q=qc, r=rc, slack=0, seed=seed)
    fqc, frc = qf.fingerprints(canon, keys)

    qf_hits = []
    if not dispatch.is_pallas(mode):
        for c, s in zip(qf_cfgs, qf_states):
            fq, fr = qf._requotient(fqc, frc, canon, c)
            qf_hits.append((s.n > 0) & qf.lookup_exact(c, s, fq, fr))
    else:
        # one canonical-fingerprint sort serves every level: requotient
        # is monotone, so the batch is simultaneously sorted by each
        # level's quotient
        B0 = keys.shape[0]
        iota = jnp.arange(B0, dtype=jnp.int32)
        _, _, perm = jax.lax.sort((fqc, frc, iota), num_keys=2)
        pad = (-B0) % tile_t
        osort = (
            jnp.concatenate([perm, jnp.full((pad,), perm[-1])]) if pad else perm
        )

        planes, fq_lv, fr_lv, fq_raw, fr_raw = [], [], [], [], []
        for c, s in zip(qf_cfgs, qf_states):
            fq, fr = qf._requotient(fqc, frc, canon, c)
            fq_raw.append(fq)
            fr_raw.append(fr)
            fq_lv.append(fq[osort])
            fr_lv.append(fr[osort])
            planes.append(
                (
                    s.rem.astype(jnp.int32),
                    s.occ.astype(jnp.int32),
                    s.shf.astype(jnp.int32),
                    s.con.astype(jnp.int32),
                )
            )
        hitm_s, ovfm_s = cascade_probe_tiles(
            planes,
            fq_lv,
            fr_lv,
            tile_t=tile_t,
            wblk=wblk,
            interpret=dispatch.pallas_interpret(mode),
        )
        hitm = jnp.zeros((B0,), jnp.int32).at[osort].set(hitm_s, mode="drop")
        ovfm = jnp.zeros((B0,), jnp.int32).at[osort].max(ovfm_s, mode="drop")

        for lvl, (c, s) in enumerate(zip(qf_cfgs, qf_states)):
            hit_l = ((hitm >> lvl) & 1) > 0
            ovf_l = ((ovfm >> lvl) & 1) > 0

            def resolve(args, c=c, s=s, lvl=lvl):
                hit_l, ovf_l = args
                exact = qf.lookup_exact(c, s, fq_raw[lvl], fr_raw[lvl])
                return jnp.where(ovf_l, exact, hit_l)

            hit_l = jax.lax.cond(
                jnp.any(ovf_l), resolve, lambda a: a[0], (hit_l, ovf_l)
            )
            qf_hits.append((s.n > 0) & hit_l)

    # frozen levels: their probe positions hash the fingerprint (not
    # monotone in it), so they keep their own position-sorted 3-gather
    # pass instead of joining the fused grid
    fuse_hits = [
        fuse_lookup(c, s, fqc, frc, mode=mode)
        for c, s in zip(fuse_cfgs, fuse_states)
    ]
    return tuple(qf_hits) + tuple(fuse_hits)


def cascade_lookup(
    qf_cfgs,
    qf_states,
    fuse_cfgs,
    fuse_states,
    keys: jnp.ndarray,
    *,
    mode: str | None = None,
    interpret: bool | None = None,
    tile_t: int = 128,
    wblk: int = 1024,
):
    """Probe a whole cascade stack in one fused pass.

    ``qf_cfgs``/``qf_states`` are the unfrozen structures top-down (Q0
    first), ``fuse_cfgs``/``fuse_states`` the frozen levels; all must
    share the fingerprint width ``p`` and seed.  Returns one bool (B,)
    hit array per structure, QF structures first, in argument order —
    the caller ORs (contains) or schedules (probe I/O accounting) them.
    """
    return _cascade_lookup(
        tuple(qf_cfgs),
        tuple(fuse_cfgs),
        tuple(qf_states),
        tuple(fuse_states),
        keys,
        mode=dispatch.resolve(mode, interpret),
        tile_t=tile_t,
        wblk=wblk,
    )


# ---------------------------------------------------------------------------
# Chunked / span append (incremental migration)
# ---------------------------------------------------------------------------


def _span_math(cfg, fq, fr, k, last_pos, last_fq):
    """Closed-form append positions for a carried sorted span.

    The probe recurrence ``pos[i] = max(pos[i-1] + 1, fq[i])``
    closed-forms to ``i + max(last_pos + 1, cummax(fq - i))`` over the
    whole span at once — chunk boundaries are irrelevant to the math,
    which is what lets a multi-chunk drain run as ONE pass.
    """
    t = cfg.total_slots
    kk = jnp.asarray(k, jnp.int32)
    idx = jnp.arange(fq.shape[0], dtype=jnp.int32)
    valid = idx < kk

    d = jnp.where(valid, fq - idx, -INT32_MAX)
    pos = idx + jnp.maximum(last_pos + 1, jax.lax.cummax(d))
    overflow = jnp.any(valid & (pos >= t))
    spos = jnp.where(valid, pos, INT32_MAX)

    prev_fq = jnp.roll(fq, 1).at[0].set(last_fq)
    con_bits = valid & (fq == prev_fq)
    shf_bits = valid & (pos != fq)

    last = jnp.clip(kk - 1, 0, fq.shape[0] - 1)
    new_last_pos = jnp.where(kk > 0, pos[last], last_pos)
    new_last_fq = jnp.where(kk > 0, fq[last], last_fq)
    return kk, valid, spos, con_bits, shf_bits, overflow, new_last_pos, new_last_fq


@functools.partial(
    jax.jit, static_argnums=(0,), static_argnames=("mode", "block_s")
)
def _build_span(cfg, state, fq, fr, k, last_pos, last_fq, *, mode, block_s):
    kk, valid, spos, con_bits, shf_bits, overflow, nlp, nlf = _span_math(
        cfg, fq, fr, k, last_pos, last_fq
    )
    occ = state.occ.at[jnp.where(valid, fq, INT32_MAX)].set(True, mode="drop")

    if dispatch.is_pallas(mode):
        # kernel-resident append: positions strictly increase past every
        # slot the partial state has written, so the freshly emitted
        # planes and the existing ones touch DISJOINT slots (all-zero on
        # the other side) and OR-merge exactly
        meta_bits = con_bits.astype(jnp.int32) | (shf_bits.astype(jnp.int32) << 1)
        rem_k, meta_k = qf_build_planes(
            spos,
            fr,
            meta_bits,
            cfg.total_slots,
            block_s=block_s,
            interpret=dispatch.pallas_interpret(mode),
        )
        new = qf.QFState(
            rem=state.rem | rem_k.astype(jnp.uint32),
            occ=occ,
            shf=state.shf | ((meta_k >> 1) > 0),
            con=state.con | ((meta_k & 1) > 0),
            n=state.n + kk,
            overflow=state.overflow | overflow,
        )
    else:
        new = qf.QFState(
            rem=state.rem.at[spos].set(fr, mode="drop"),
            occ=occ,
            shf=state.shf.at[spos].set(shf_bits, mode="drop"),
            con=state.con.at[spos].set(con_bits, mode="drop"),
            n=state.n + kk,
            overflow=state.overflow | overflow,
        )
    return new, nlp, nlf


def build_span(
    cfg: qf.QFConfig,
    state: qf.QFState,
    fq: jnp.ndarray,
    fr: jnp.ndarray,
    k,
    last_pos,
    last_fq,
    *,
    mode: str | None = None,
    interpret: bool | None = None,
    block_s: int = 256,
):
    """Append a bounded sorted span (first ``k`` rows valid) to a
    partially built QF in one pass — the multi-chunk form of
    ``build_chunk``, bit-identical to folding the span in chunk by
    chunk (the carried scan closed-forms over any span length).

    Same contract as ``build_chunk``: ``state`` holds exactly the
    entries appended so far in sorted order, ``(last_pos, last_fq)``
    carry across calls.  Under the pallas modes the plane
    materialization runs as the tiled build grid (one launch for the
    whole span); xla mode scatters directly.  Returns
    ``(state, last_pos, last_fq)``.
    """
    return _build_span(
        cfg,
        state,
        fq,
        fr,
        k,
        last_pos,
        last_fq,
        mode=dispatch.resolve(mode, interpret),
        block_s=block_s,
    )


@functools.partial(jax.jit, static_argnums=(0,))
def build_chunk(
    cfg: qf.QFConfig,
    state: qf.QFState,
    fq: jnp.ndarray,
    fr: jnp.ndarray,
    k,
    last_pos,
    last_fq,
):
    """Chunked build-plane entry: append one bounded sorted chunk to a
    partially built QF (the per-insert incremental-resize step).

    ``state`` must hold exactly the entries appended so far, built in
    sorted fingerprint order; ``(last_pos, last_fq)`` carry the probe
    scan across chunk boundaries (both -1 before the first chunk).  The
    first ``k`` rows of ``(fq, fr)`` are valid and sorted, and every
    fingerprint sorts at-or-after the carried ``last_fq``.  Appending
    chunk by chunk reproduces ``build_sorted`` of the full prefix
    bit-for-bit: the probe recurrence ``pos[i] = max(pos[i-1] + 1,
    fq[i])`` closed-forms to ``i + max(last_pos + 1, cummax(fq - i))``,
    so positions strictly increase and chunks never overwrite.

    O(chunk) work — a handful of scattered single-slot writes, the
    right shape for the per-insert path on every backend.  Multi-chunk
    drains (``finish``) route through :func:`build_span`, which runs
    the same math as one tiled kernel grid / one fused scatter instead
    of a host loop of these.

    Returns ``(state, last_pos, last_fq)`` with the carries advanced.
    """
    kk, valid, spos, con_bits, shf_bits, overflow, nlp, nlf = _span_math(
        cfg, fq, fr, k, last_pos, last_fq
    )
    new = qf.QFState(
        rem=state.rem.at[spos].set(fr, mode="drop"),
        occ=state.occ.at[jnp.where(valid, fq, INT32_MAX)].set(True, mode="drop"),
        shf=state.shf.at[spos].set(shf_bits, mode="drop"),
        con=state.con.at[spos].set(con_bits, mode="drop"),
        n=state.n + kk,
        overflow=state.overflow | overflow,
    )
    return new, nlp, nlf


# ---------------------------------------------------------------------------
# Blocked-Bloom bin ops
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("ncells", "mode", "block_s")
)
def _bloom_counts(idx_flat, *, ncells, mode, block_s):
    if not dispatch.is_pallas(mode):
        return (
            jnp.zeros((ncells,), jnp.int32)
            .at[idx_flat]
            .add(1, mode="drop")
        )
    sidx = jnp.sort(idx_flat)
    counts_k, fits = bloom_count_tiles(
        sidx, ncells, block_s=block_s, interpret=dispatch.pallas_interpret(mode)
    )
    n_tiles = fits.shape[0]
    t_pad = n_tiles * block_s

    def resolve(counts_k):
        # hot tiles (bins denser than the item window) recount by
        # scatter; insert is a commutative aggregation, so a per-tile
        # mix of kernel and scatter counts is exact
        ref = (
            jnp.zeros((t_pad,), jnp.int32)
            .at[sidx]
            .add(1, mode="drop")
            .reshape(n_tiles, block_s)
        )
        ck = counts_k.reshape(n_tiles, block_s)
        return jnp.where(fits[:, None], ck, ref).reshape(t_pad)

    counts = jax.lax.cond(
        jnp.all(fits), lambda c: c, resolve, counts_k
    )
    return counts[:ncells]


def bloom_counts(
    idx_flat: jnp.ndarray,
    ncells: int,
    *,
    mode: str | None = None,
    interpret: bool | None = None,
    block_s: int = 512,
):
    """Aggregate a flat batch of cell indices into an int32 counts plane.

    The shared write-side primitive of the Bloom family: insert is
    ``cells + counts`` (counting) or ``cells | (counts > 0)`` (plain),
    delete is ``cells - counts`` — all commutative, so the kernel's
    per-tile aggregation composes exactly with the scatter fallback.
    Out-of-range indices (masked keys) drop.
    """
    return _bloom_counts(
        idx_flat,
        ncells=ncells,
        mode=dispatch.resolve(mode, interpret),
        block_s=block_s,
    )


@functools.partial(jax.jit, static_argnames=("mode", "tile_t", "wblk"))
def _bloom_probe(cells, idx, *, mode, tile_t, wblk):
    if not dispatch.is_pallas(mode):
        return jnp.all(cells[idx] > 0, axis=1)

    B0 = idx.shape[0]
    # blocked layout: all k probes of a key share one bin, so sorting by
    # the per-key min makes tile windows contiguous bin ranges
    osort = dispatch.sorted_tile_order(jnp.min(idx, axis=1), tile_t)
    hit_s, ovf_s = bloom_probe_tiles(
        cells.astype(jnp.int32),
        idx[osort],
        tile_t=tile_t,
        wblk=wblk,
        interpret=dispatch.pallas_interpret(mode),
    )
    hit = jnp.zeros((B0,), jnp.int32).at[osort].set(hit_s, mode="drop")
    ovf = jnp.zeros((B0,), jnp.int32).at[osort].max(ovf_s, mode="drop")

    def resolve(args):
        hit, ovf = args
        exact = jnp.all(cells[idx] > 0, axis=1)
        return jnp.where(ovf > 0, exact, hit > 0)

    return jax.lax.cond(
        jnp.any(ovf > 0), resolve, lambda a: a[0] > 0, (hit, ovf)
    )


def bloom_probe(
    cells: jnp.ndarray,
    idx: jnp.ndarray,
    *,
    mode: str | None = None,
    interpret: bool | None = None,
    tile_t: int = 128,
    wblk: int = 4096,
):
    """AND-of-k membership over a cell plane for (B, k) cell indices.

    Pallas modes tile bin-sorted queries over prefetched cell windows
    (the blocked-Bloom read path); xla mode gathers directly.  Queries
    whose bins outrun their tile window resolve on the exact gather.
    """
    return _bloom_probe(
        cells,
        idx,
        mode=dispatch.resolve(mode, interpret),
        tile_t=tile_t,
        wblk=wblk,
    )
