"""Logical-axis sharding: one place that decides how tensors map to the mesh.

Every tensor in the model is annotated with *logical* axis names
("batch", "seq", "heads", ...).  A :class:`ShardingRules` object maps
logical names to mesh axes, with per-architecture fallbacks (e.g. an
8-expert MoE cannot shard experts over a 16-way model axis, so experts
fall back to replicated and the per-expert ffn dim takes the model
axis).  ``constrain`` is a no-op outside an active rules context, so
the same model code runs single-device (smoke tests) and on the
production mesh (dry-run) unchanged.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_STATE = threading.local()


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@dataclass
class ShardingRules:
    mesh: Mesh
    mapping: dict[str, Any] = field(default_factory=dict)

    @classmethod
    def for_config(cls, mesh: Mesh, cfg=None, *, seq_shard: bool = True,
                   decode: bool = False) -> "ShardingRules":
        """Default DP/FSDP + TP(+SP) rules for the production mesh.

        data-parallel axes ("pod","data") shard batch and the FSDP
        (scan-over-layers) param dim; "model" shards heads / ffn /
        vocab (Megatron TP) and the residual-stream sequence dim
        between blocks (sequence parallelism).
        """
        names = mesh.axis_names
        dp = tuple(a for a in ("pod", "data") if a in names)
        tp = "model" if "model" in names else None
        tp_size = _axis_size(mesh, tp)
        dp_size = _axis_size(mesh, dp)

        def fits(dim: int, over=tp, size=None) -> bool:
            n = size if size is not None else _axis_size(mesh, over)
            return over is not None and dim > 0 and dim % n == 0

        m = {
            # ZeRO/FSDP: params' d_model dim shards over the DP axes; on
            # activations "embed" dedups to None because "batch" already
            # consumed the DP axes (ShardingRules.spec drops reused axes).
            "batch": dp,
            "seq": tp if seq_shard else None,  # SP between blocks
            "kv_seq": None,
            "embed": None,
            "heads": tp,
            "kv_heads": None,  # set per-config below
            "head_dim": None,
            "qk_dim": None,
            "ffn": tp,
            "vocab": tp,
            "layers": None,
            "experts": None,
            "expert_ffn": tp,
            "lru": tp,
            "ssm_inner": tp,
            "state": None,
            "conv": None,
        }
        if cfg is not None:
            if fits(cfg.d_model, dp, dp_size):
                m["embed"] = dp
            if not fits(cfg.n_heads):
                m["heads"] = None
            if not fits(cfg.vocab_size):
                m["vocab"] = None
            if cfg.d_ff and not fits(cfg.d_ff):
                m["ffn"] = None
            if cfg.n_kv_heads and fits(cfg.n_kv_heads):
                m["kv_heads"] = tp
            elif decode and cfg.n_kv_heads and fits(cfg.head_dim):
                # decode with few KV heads: shard the KV cache's head_dim
                # (the scores contraction all-reduces); queries follow so
                # q/k layouts stay consistent
                m["head_dim"] = tp
                m["heads"] = None
            # train with kv < tp: KV stays replicated (q sharded by heads;
            # GSPMD splits k locally during the grouped contraction)
            if cfg.n_experts:
                if fits(cfg.n_experts):
                    m["experts"] = tp  # true expert parallelism
                    m["expert_ffn"] = None
                else:
                    m["experts"] = None  # replicate experts, TP the ffn dim
                    m["expert_ffn"] = tp if fits(cfg.moe_d_ff or cfg.d_ff) else None
            if cfg.attn_kind == "mla":
                m["kv_heads"] = None
                m["head_dim"] = None
            if cfg.lru_width and not fits(cfg.lru_width):
                m["lru"] = None
        return cls(mesh=mesh, mapping=m)

    def spec(self, axes: tuple, shape: tuple = None) -> PartitionSpec:
        """PartitionSpec for logical axes; with ``shape``, any mapping
        whose mesh-axis product does not divide the dim falls back to
        replicated (jit in_shardings demand exact divisibility)."""
        parts, used = [], set()
        for i, a in enumerate(axes):
            if a is None:
                parts.append(None)
                continue
            mapped = self.mapping.get(a)
            if mapped is None:
                parts.append(None)
                continue
            tup = (mapped,) if isinstance(mapped, str) else tuple(mapped)
            tup = tuple(x for x in tup if x not in used)
            if shape is not None and tup:
                n = 1
                for x in tup:
                    n *= self.mesh.shape[x]
                if n == 0 or shape[i] % n != 0:
                    parts.append(None)
                    continue
            used.update(tup)
            parts.append(tup if len(tup) > 1 else (tup[0] if tup else None))
        return PartitionSpec(*parts)

    def sharding(self, axes: tuple, shape: tuple = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape))


def active_rules() -> Optional[ShardingRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def constrain(x, *axes):
    """with_sharding_constraint by logical axes (no-op without rules).

    Shape-aware: a logical mapping that does not divide the concrete
    dim is dropped rather than padded."""
    rules = active_rules()
    if rules is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"rank mismatch: {axes} vs {x.shape}")
    return jax.lax.with_sharding_constraint(
        x, rules.sharding(tuple(axes), tuple(x.shape))
    )
