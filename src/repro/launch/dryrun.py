import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod or
2x16x16 multi-pod), constructs abstract (ShapeDtypeStruct) inputs and
parameter/optimizer/cache shardings, and runs ``.lower().compile()`` on
the real step function.  Success proves the distribution config is
coherent: every sharding divides, every collective is supported, and
``memory_analysis()`` shows the per-chip footprint.  Roofline terms are
derived from ``cost_analysis()`` + the optimized HLO (see roofline.py)
and written as JSON for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

import argparse
import json
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shd
from repro.configs import ARCHS, get_config
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_applicable, input_specs
from repro.models import model
from repro.serve.serve_step import cache_pspecs
from repro.train import optimizer as optim
from repro.train import train_step as ts

# per-arch overrides that make the big cells fit 16 GiB/chip
DRYRUN_OVERRIDES = {
    "grok-1-314b": dict(opt_dtype="bfloat16", microbatches=8),
    "starcoder2-15b": dict(opt_dtype="bfloat16"),
    "deepseek-v2-lite-16b": dict(opt_dtype="bfloat16", microbatches=2),
    "whisper-large-v3": dict(microbatches=2),
    "qwen2-vl-7b": dict(microbatches=2),
    "recurrentgemma-9b": dict(microbatches=4),
}


def _named(mesh, tree_pspec):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        tree_pspec,
        is_leaf=lambda x: isinstance(x, P),
    )


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool, microbatches: int = 1):
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rules = shd.ShardingRules.for_config(mesh, cfg, decode=(spec.kind == "decode"))
    ov = DRYRUN_OVERRIDES.get(arch, {})
    ocfg = optim.OptConfig(opt_dtype=ov.get("opt_dtype", "float32"))
    if microbatches == 1:
        microbatches = ov.get("microbatches", 1)

    t0 = time.time()
    if spec.kind == "train":
        state_abs = ts.abstract_state(cfg, ocfg)
        state_sh = _named(mesh, ts.state_pspecs(cfg, ocfg, rules))
        batch_abs = input_specs(cfg, shape_name)["batch"]
        bspec = {
            k: rules.spec(("batch",) + (None,) * (v.ndim - 1), v.shape)
            for k, v in batch_abs.items()
        }
        batch_sh = _named(mesh, bspec)
        step = ts.make_train_step(cfg, ocfg, microbatches=microbatches, remat=True)

        def wrapped(state, batch):
            with shd.use_rules(rules):
                return step(state, batch)

        with mesh:
            lowered = jax.jit(
                wrapped,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state_abs, batch_abs)
    elif spec.kind == "prefill":
        params_abs = model.abstract(cfg)
        params_sh = _named(mesh, model.partition_pspecs(cfg, rules))
        batch_abs = input_specs(cfg, shape_name)["batch"]
        bspec = {
            k: rules.spec(("batch",) + (None,) * (v.ndim - 1), v.shape)
            for k, v in batch_abs.items()
        }
        batch_sh = _named(mesh, bspec)

        def prefill_step(params, batch):
            with shd.use_rules(rules):
                return model.prefill(params, cfg, batch, remat=True, headroom=0)

        with mesh:
            lowered = jax.jit(
                prefill_step, in_shardings=(params_sh, batch_sh)
            ).lower(params_abs, batch_abs)
    else:  # decode
        params_abs = model.abstract(cfg)
        params_sh = _named(mesh, model.partition_pspecs(cfg, rules))
        specs = input_specs(cfg, shape_name)
        cache_abs, tokens_abs = specs["cache"], specs["tokens"]
        cache_sh = _named(mesh, cache_pspecs(cfg, rules, cache_abs))
        tok_sh = NamedSharding(
            mesh, rules.spec(("batch", None), tokens_abs.shape)
        )

        def serve_step(params, cache, tokens):
            with shd.use_rules(rules):
                return model.decode_step(params, cfg, cache, tokens)

        with mesh:
            lowered = jax.jit(
                serve_step,
                in_shardings=(params_sh, cache_sh, tok_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            ).lower(params_abs, cache_abs, tokens_abs)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # trip-count-aware analysis (raw cost_analysis counts loop bodies once)
    from repro.launch import hlo_analysis as H

    hlo = H.analyze(compiled.as_text())
    coll = hlo["collectives"]
    mf = rf.model_flops_estimate(cfg, spec.kind, spec.batch, spec.seq)
    roof = rf.Roofline(
        flops=float(hlo["flops"]),
        bytes_accessed=float(hlo["bytes"]),
        coll_bytes=float(coll["total"]),
        chips=chips,
        model_flops=mf,
    )
    arg_b = int(mem.argument_size_in_bytes)
    tmp_b = int(mem.temp_size_in_bytes)
    out_b = int(mem.output_size_in_bytes)
    alias_b = int(mem.alias_size_in_bytes)
    hbm = arg_b + tmp_b + out_b - alias_b
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": arg_b,
            "temp_bytes": tmp_b,
            "output_bytes": out_b,
            "alias_bytes": alias_b,
            "hbm_bytes_per_device": hbm,
            "fits_16GiB": hbm < 16 * 2**30,
        },
        "collectives": coll,
        "roofline": roof.as_dict(),
        "raw_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
    }
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            try:
                res = lower_cell(
                    arch, shape, multi_pod=mp, microbatches=args.microbatches
                )
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                res = {
                    "arch": arch, "shape": shape,
                    "mesh": "2x16x16" if mp else "16x16",
                    "status": "error", "error": f"{type(e).__name__}: {e}",
                }
                failures += 1
            with open(path, "w") as f:
                json.dump(res, f, indent=2)
            status = res["status"]
            extra = ""
            if status == "ok":
                m = res["memory"]
                extra = (
                    f" hbm/dev={m['hbm_bytes_per_device']/2**30:.2f}GiB"
                    f" fits={m['fits_16GiB']}"
                    f" bound={res['roofline']['bound']}"
                    f" mfu={res['roofline']['roofline_mfu']:.3f}"
                )
            print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
