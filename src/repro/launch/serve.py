"""Serving driver: batched prefill + decode with the AMQ prefix cache.

Demonstrates the paper's Webtable pattern in the serving plane: a
quotient filter in front of the (simulated remote) prefix-KV store
answers "is this prefix cached?" without paying the remote round trip
for misses.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
      --requests 16 --gen 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, make_smoke
from repro.models import model
from repro.serve.prefix_cache import PrefixCacheFilter
from repro.serve.serve_step import sample_greedy


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = make_smoke(cfg)
    params = model.init(cfg, args.seed)
    rng = np.random.default_rng(args.seed)

    pcache = PrefixCacheFilter(q=16, r=14)
    B = args.requests
    prompts = rng.integers(0, cfg.vocab_size, (B, args.prompt_len))
    # half the requests repeat earlier prompts (cache hits)
    prompts[B // 2 :] = prompts[: B - B // 2]

    hits = pcache.check_and_insert(prompts)
    print(f"[serve] prefix-cache hits: {int(hits.sum())}/{B} "
          f"(repeats should hit)")

    batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)),
            jnp.dtype(cfg.act_dtype),
        )
    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, cfg, b, remat=False)
    )(params, batch)
    tok = sample_greedy(logits)[:, None]
    decode = jax.jit(lambda p, c, t: model.decode_step(p, cfg, c, t))
    out = [tok]
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = sample_greedy(logits)[:, None]
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"[serve] generated {B}x{args.gen} tokens in {dt:.2f}s "
          f"({B*args.gen/dt:.1f} tok/s); sample: {np.asarray(gen[0])[:8]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
