"""Assigned input-shape grid + abstract input construction.

Every (arch x shape) cell lowers exactly one step function:
  train_4k    -> train_step   (loss + grads + optimizer update)
  prefill_32k -> prefill_step (full-sequence forward + cache build)
  decode_32k  -> serve_step   (one new token against a seq_len KV cache)
  long_500k   -> serve_step   (sub-quadratic archs only; see DESIGN.md §5)
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import model


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cell_applicable(cfg, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} ({cfg.family}) is full-attention — skipped per assignment"
        )
    return True, ""


def input_specs(cfg, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    spec = SHAPES[shape_name]
    i32 = jnp.int32
    if spec.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((spec.batch, spec.seq), i32),
            "targets": jax.ShapeDtypeStruct((spec.batch, spec.seq), i32),
        }
        if cfg.is_encoder_decoder:
            batch["frames"] = jax.ShapeDtypeStruct(
                (spec.batch, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.act_dtype)
            )
        return {"batch": batch}
    if spec.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((spec.batch, spec.seq), i32)}
        if cfg.is_encoder_decoder:
            batch["frames"] = jax.ShapeDtypeStruct(
                (spec.batch, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.act_dtype)
            )
        return {"batch": batch}
    # decode: one new token against a seq-long cache
    cache = jax.eval_shape(
        lambda: model.init_cache(cfg, spec.batch, spec.seq, cfg.act_dtype)
    )
    return {
        "tokens": jax.ShapeDtypeStruct((spec.batch, 1), i32),
        "cache": cache,
    }
