"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so a
scan-over-layers model under-reports FLOPs/bytes/collectives by ~n_layers.
This module parses the optimized HLO text, builds the computation call
graph (while bodies carry ``known_trip_count`` in backend_config), and
aggregates:

  * flops       — dots exactly (2·M·K·N via operand-shape lookup),
                  elementwise/reduce approximately (1/elt)
  * bytes       — operand + output bytes per top-level op; fusion
                  internals excluded (a fusion is one read + one write)
  * collectives — per-kind byte totals (output-shape convention),
                  multiplied through loop trip counts

Used by the dry-run and the §Perf iteration loop as the "profile".
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "pred": 1, "s8": 1, "u8": 1, "token": 0, "opaque": 0,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<shape>\(.*?\)|\S+)\s+"
    r"(?P<op>[\w\-]+)\((?P<args>.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s+\(.*\)\s*->.*\{")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "and", "or", "xor", "not", "compare", "select", "exponential",
    "tanh", "log", "rsqrt", "sqrt", "power", "negate", "abs",
    "floor", "ceil", "sign", "clamp", "cosine", "sine", "logistic",
    "expm1", "log1p", "round-nearest-even", "remainder", "atan2",
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_elems_bytes(text: str) -> tuple[int, int]:
    """(elements, bytes) summed over every dtype[dims] in text."""
    elems = tot = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        tot += n * _DTYPE_BYTES[dt]
    return elems, tot


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str

    @property
    def out_elems(self) -> int:
        return _shape_elems_bytes(self.shape)[0]

    @property
    def out_bytes(self) -> int:
        return _shape_elems_bytes(self.shape)[1]


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)  # instr name -> shape str


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, Computation] = {}
        self.entry: str | None = None
        cur: Computation | None = None
        for raw in text.splitlines():
            line = raw.rstrip()
            mc = _COMP_RE.match(line.strip())
            if mc and (
                line.startswith("ENTRY")
                or line.startswith("%")
                or raw.startswith("ENTRY")
            ):
                cur = Computation(mc.group("name"))
                self.computations[cur.name] = cur
                if line.strip().startswith("ENTRY") or raw.startswith("ENTRY"):
                    self.entry = cur.name
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            mi = _INSTR_RE.match(line)
            if mi:
                ins = Instr(
                    mi.group("name"), mi.group("shape"), mi.group("op"), line
                )
                cur.instrs.append(ins)
                cur.shapes[ins.name] = ins.shape
        if self.entry is None and self.computations:
            # fall back: largest computation
            self.entry = max(
                self.computations, key=lambda k: len(self.computations[k].instrs)
            )
        self._memo_flops: dict[str, float] = {}
        self._memo_bytes: dict[str, float] = {}
        self._memo_coll: dict[str, dict] = {}

    # -- helpers -------------------------------------------------------------

    def _operand_shape(self, comp: Computation, rest: str, idx: int):
        names = _OPERAND_RE.findall(rest.split("(", 1)[1] if "(" in rest else rest)
        if idx >= len(names):
            return None
        return comp.shapes.get(names[idx])

    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        out = ins.out_elems
        lhs_shape = self._operand_shape(comp, ins.rest, 0)
        m = _CONTRACT_RE.search(ins.rest)
        contracted = 1
        if lhs_shape and m:
            dims_txt = _SHAPE_RE.search(lhs_shape)
            if dims_txt and dims_txt.group(2):
                dims = [int(d) for d in dims_txt.group(2).split(",")]
                for ci in m.group(1).split(","):
                    if ci != "" and int(ci) < len(dims):
                        contracted *= dims[int(ci)]
        return 2.0 * out * contracted

    def _conv_flops(self, comp: Computation, ins: Instr) -> float:
        # depthwise-ish approximation: 2 * output_elems * kernel_spatial
        rhs_shape = self._operand_shape(comp, ins.rest, 1)
        k = 1
        if rhs_shape:
            m = _SHAPE_RE.search(rhs_shape)
            if m and m.group(2):
                dims = [int(d) for d in m.group(2).split(",")]
                k = max(1, int(__import__("numpy").prod(dims[:-1])))
        return 2.0 * ins.out_elems * min(k, 10_000)

    def _trip(self, ins: Instr) -> int:
        m = _TRIP_RE.search(ins.rest)
        return int(m.group(1)) if m else 1

    def _called(self, ins: Instr) -> list[str]:
        out = []
        for rx in (_CALLS_RE, _COND_RE, _BODY_RE):
            m = rx.search(ins.rest)
            if m:
                out.append(m.group(1))
        return out

    # -- aggregates ------------------------------------------------------------

    def flops(self, comp_name: str | None = None) -> float:
        name = comp_name or self.entry
        if name in self._memo_flops:
            return self._memo_flops[name]
        comp = self.computations.get(name)
        if comp is None:
            return 0.0
        total = 0.0
        self._memo_flops[name] = 0.0  # cycle guard
        for ins in comp.instrs:
            if ins.op == "dot":
                total += self._dot_flops(comp, ins)
            elif ins.op == "convolution":
                total += self._conv_flops(comp, ins)
            elif ins.op in _ELEMENTWISE:
                total += ins.out_elems
            elif ins.op in ("reduce", "reduce-window"):
                sh = self._operand_shape(comp, ins.rest, 0)
                total += _shape_elems_bytes(sh)[0] if sh else ins.out_elems
            elif ins.op == "while":
                t = self._trip(ins)
                total += t * sum(self.flops(c) for c in self._called(ins))
            elif ins.op in ("fusion", "call", "conditional", "map", "async-start"):
                total += sum(self.flops(c) for c in self._called(ins))
        self._memo_flops[name] = total
        return total

    def bytes_accessed(self, comp_name: str | None = None) -> float:
        """Top-level op traffic; fusion = operands + output only."""
        name = comp_name or self.entry
        if name in self._memo_bytes:
            return self._memo_bytes[name]
        comp = self.computations.get(name)
        if comp is None:
            return 0.0
        self._memo_bytes[name] = 0.0
        total = 0.0
        for ins in comp.instrs:
            if ins.op == "while":
                t = self._trip(ins)
                total += t * sum(self.bytes_accessed(c) for c in self._called(ins))
            elif ins.op in ("call", "conditional"):
                total += sum(self.bytes_accessed(c) for c in self._called(ins))
            elif ins.op in (
                "parameter", "constant", "get-tuple-element", "tuple", "bitcast"
            ):
                continue
            elif ins.op == "dynamic-update-slice":
                # in-place update: traffic = 2 x update slice, not the buffer
                onames = _OPERAND_RE.findall(ins.rest.split("(", 1)[1])
                upd = (
                    _shape_elems_bytes(comp.shapes.get(onames[1], ""))[1]
                    if len(onames) > 1
                    else 0
                )
                total += 2 * upd
            elif ins.op == "dynamic-slice":
                total += 2 * ins.out_bytes
            else:
                in_place_fusion = False
                if ins.op == "fusion":
                    for c in self._called(ins):
                        callee = self.computations.get(c)
                        if (
                            callee
                            and callee.instrs
                            and callee.instrs[-1].op == "dynamic-update-slice"
                        ):
                            root = callee.instrs[-1]
                            on = _OPERAND_RE.findall(root.rest.split("(", 1)[1])
                            upd = (
                                _shape_elems_bytes(callee.shapes.get(on[1], ""))[1]
                                if len(on) > 1
                                else 0
                            )
                            total += 2 * upd
                            in_place_fusion = True
                if not in_place_fusion:
                    # operands + output (fusion internals excluded by design)
                    onames = _OPERAND_RE.findall(
                        ins.rest.split("(", 1)[1] if "(" in ins.rest else ""
                    )
                    ob = sum(
                        _shape_elems_bytes(comp.shapes.get(n, ""))[1] for n in onames
                    )
                    total += ob + ins.out_bytes
        self._memo_bytes[name] = total
        return total

    def collective_bytes(self, comp_name: str | None = None) -> dict:
        name = comp_name or self.entry
        if name in self._memo_coll:
            return dict(self._memo_coll[name])
        comp = self.computations.get(name)
        out = {k: 0.0 for k in _COLLECTIVES}
        if comp is None:
            return out
        self._memo_coll[name] = dict(out)
        for ins in comp.instrs:
            base = ins.op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                out[base] += ins.out_bytes
            elif ins.op == "while":
                t = self._trip(ins)
                for c in self._called(ins):
                    sub = self.collective_bytes(c)
                    for k in _COLLECTIVES:
                        out[k] += t * sub[k]
            elif ins.op in ("fusion", "call", "conditional"):
                for c in self._called(ins):
                    sub = self.collective_bytes(c)
                    for k in _COLLECTIVES:
                        out[k] += sub[k]
        out["total"] = sum(out[k] for k in _COLLECTIVES)
        self._memo_coll[name] = dict(out)
        return out


def top_collectives(hlo_text: str, k: int = 12) -> list[dict]:
    """Largest collective ops (per-device output bytes x trip count) with
    their source op_name metadata — the §Perf 'profile'."""
    mod = HloModule(hlo_text)
    # trip multiplier per computation (entry=1, while bodies *= trips)
    mult: dict[str, int] = {mod.entry: 1}
    frontier = [mod.entry]
    while frontier:
        name = frontier.pop()
        comp = mod.computations.get(name)
        if comp is None:
            continue
        for ins in comp.instrs:
            t = mod._trip(ins) if ins.op == "while" else 1
            for c in mod._called(ins):
                m = mult.get(name, 1) * t
                if mult.get(c, 0) < m:
                    mult[c] = m
                    frontier.append(c)
    out = []
    meta_re = re.compile(r'op_name="([^"]+)"')
    for name, comp in mod.computations.items():
        for ins in comp.instrs:
            base = ins.op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                m = meta_re.search(ins.rest)
                out.append(
                    {
                        "kind": base,
                        "bytes": ins.out_bytes * mult.get(name, 1),
                        "per_call_bytes": ins.out_bytes,
                        "trips": mult.get(name, 1),
                        "shape": ins.shape[:64],
                        "source": (m.group(1)[:120] if m else ""),
                    }
                )
    out.sort(key=lambda d: -d["bytes"])
    return out[:k]


def analyze(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    coll = mod.collective_bytes()
    return {
        "flops": mod.flops(),
        "bytes": mod.bytes_accessed(),
        "collectives": coll,
    }
