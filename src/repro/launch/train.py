"""End-to-end training driver.

Wires together: dedup data pipeline (the paper's technique in the data
plane) -> sharded train step -> checkpointing (incl. filter state) ->
fault-tolerant supervision.  Runs real steps on whatever devices exist
(CPU smoke: ``--arch <id> --smoke``); on a pod the same code paths run
under the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
      --smoke --steps 20 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, make_smoke
from repro.data.pipeline import DedupPipeline, PipelineConfig
from repro.train import optimizer as optim
from repro.train import train_step as ts
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import (
    ClusterMonitor,
    FTConfig,
    TrainSupervisor,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = make_smoke(cfg)
    ocfg = optim.OptConfig(
        lr=args.lr,
        total_steps=args.steps,
        warmup_steps=max(1, args.steps // 10),
        compress_grads=args.compress_grads,
    )

    pipe = DedupPipeline(
        PipelineConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
            seed=args.seed,
        )
    )
    state = ts.init_state(cfg, ocfg, args.seed)
    step_fn = jax.jit(ts.make_train_step(cfg, ocfg, microbatches=args.microbatches))

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt and args.resume:
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(latest, jax.eval_shape(lambda: state))
            extra = ckpt.restore_extra(latest)
            if extra is not None:
                import pickle

                pipe.restore(pickle.loads(extra["pipeline"].tobytes()))
            start_step = latest
            print(f"[train] resumed from step {latest}")

    monitor = ClusterMonitor(
        [f"host{i}" for i in range(jax.process_count())], FTConfig()
    )
    sup = TrainSupervisor(
        monitor, FTConfig(), hosts_per_replica=1, current_dp=1,
        on_restore=lambda dp: None,
    )

    frames = None
    if cfg.is_encoder_decoder:
        rng = np.random.default_rng(0)
        frames = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder_seq, cfg.d_model)),
            jnp.dtype(cfg.act_dtype),
        )

    it = pipe.batches(args.steps - start_step)
    t_start = time.time()
    for step in range(start_step, args.steps):
        batch = next(it)
        if frames is not None:
            batch = dict(batch, frames=frames)

        def do_step():
            nonlocal state
            state, metrics = step_fn(state, batch)
            return metrics

        metrics = sup.run_step(do_step)
        if metrics is None:
            continue
        if step % 5 == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            tput = (step - start_step + 1) * args.batch * args.seq / (
                time.time() - t_start
            )
            print(
                f"[train] step={step} loss={loss:.4f} "
                f"lr={float(metrics['lr']):.2e} "
                f"gnorm={float(metrics['grad_norm']):.2f} "
                f"tok/s={tput:.0f} dedup_dropped={pipe.state.docs_dropped}",
                flush=True,
            )
        if ckpt and (step + 1) % args.ckpt_every == 0:
            import pickle

            snap = np.frombuffer(pickle.dumps(pipe.snapshot()), np.uint8)
            ckpt.save(step + 1, state, {"pipeline": snap}, background=True)
    if ckpt:
        ckpt.wait()
    print(
        f"[train] done: {args.steps} steps; corpus seen={pipe.state.docs_seen} "
        f"kept={pipe.state.docs_kept} dropped(dup)={pipe.state.docs_dropped}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
