"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run overrides the
host platform device count before first jax init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; the multi-pod mesh stacks 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for multi-device CPU tests (8 fake devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
