"""Roofline-term extraction from compiled (dry-run) artifacts.

Three terms per (arch x shape x mesh), in seconds:

  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bw_per_chip
  collective = collective_bytes_per_device / ICI_link_bw

cost_analysis() reports the per-device (SPMD-partitioned) module, so
per-device numbers over per-chip rates equal the assignment's
"total / (chips x rate)" formulation.  Collective bytes are not in
cost_analysis — we parse the optimized HLO and sum the output-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (output-shape convention recorded in EXPERIMENTS.md).
"""

from __future__ import annotations

import re
from dataclasses import dataclass

# TPU v5e per-chip constants (assignment-provided)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "pred": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s+(?P<shapes>\([^=]*?\)|\S+)\s+(?P<op>"
    + "|".join(_COLLECTIVES)
    + r")(?:-start|-done)?[\.(]"
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes by collective kind (output-shape convention)."""
    out = {k: 0 for k in _COLLECTIVES}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        if "-done" in line.split("=")[1][:160]:
            continue  # async pair: count the -start only
        out[m.group("op")] += _shape_bytes(m.group("shapes"))
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    chips: int
    model_flops: float = 0.0  # analytic 6·N·D (or serve equivalent)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bound(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Perfect-overlap lower bound: max of the three engines."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global) — remat/redundancy waste."""
        total_hlo = self.flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def mfu(self) -> float:
        """Roofline MFU: useful model FLOPs over peak at the step-time
        lower bound."""
        t = self.step_time
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "coll_bytes_per_device": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bound": self.bound,
            "step_time_lb_s": self.step_time,
            "model_flops": self.model_flops,
            "useful_flop_fraction": self.useful_flop_fraction,
            "roofline_mfu": self.mfu,
            "chips": self.chips,
        }


def kernel_roofline(fn, *args, **kw) -> "Roofline":
    """Compiled cost-analysis of one kernel-layer op as a Roofline.

    Single chip, no collectives: the filter kernels are per-device
    streaming passes, so the roofline reduces to the compute-vs-HBM
    pair and ``t_memory`` is the TPU projection for a bandwidth-bound
    op.  Works on any backend — the CPU-compiled module's FLOP/byte
    counts are the same structural quantities the TPU module streams.
    """
    import jax

    compiled = jax.jit(fn).lower(*args, **kw).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    cost = cost or {}
    return Roofline(
        flops=float(cost.get("flops", 0.0) or 0.0),
        bytes_accessed=float(cost.get("bytes accessed", 0.0) or 0.0),
        coll_bytes=0.0,
        chips=1,
    )


def model_flops_estimate(cfg, shape_kind: str, batch: int, seq: int) -> float:
    """Analytic useful FLOPs: 6·N_active·D for training, 2·N_active·D
    (+ attention KV term) for serving."""
    n_active = cfg.active_param_count()
    if shape_kind == "train":
        base = 6.0 * n_active * batch * seq
        # attention score/value FLOPs (causal ~ S^2/2), fwd+bwd (x3)
        if cfg.attn_kind != "none":
            attn = (
                cfg.n_layers
                * batch
                * (seq * seq / 2)
                * cfg.n_heads
                * cfg.head_dim
                * 2
                * 2
                * 3
            )
            base += attn
        return base
    if shape_kind == "prefill":
        base = 2.0 * n_active * batch * seq
        if cfg.attn_kind != "none":
            base += (
                cfg.n_layers * batch * (seq * seq / 2) * cfg.n_heads * cfg.head_dim * 4
            )
        return base
    # decode: one token; attention reads the whole cache
    base = 2.0 * n_active * batch
    if cfg.attn_kind != "none":
        kv_len = seq if not cfg.attn_window else min(seq, cfg.attn_window)
        base += cfg.n_layers * batch * kv_len * cfg.n_heads * cfg.head_dim * 4
    return base
