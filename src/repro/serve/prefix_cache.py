"""AMQ-fronted prefix cache (the paper's Webtable pattern, serving-side).

A quotient filter — held as a ``repro.filters`` ``(cfg, state)`` pair —
answers "might this prompt prefix be cached?" before any remote
KV-store lookup.  False positives cost one wasted remote probe at rate
~2^-r; false negatives never happen, so a hit answer of False skips the
round trip safely.  Deletion support (QF, not BF!) matters here:
evicted prefixes are removed from the filter.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro import filters
from repro.core.fingerprint import fold_bytes


class PrefixCacheFilter:
    """Host-facing wrapper holding one functional QF ``(cfg, state)``.

    With ``auto_scale=True`` (default) the filter ingests through
    ``filters.auto_scale``, which keeps a serving tier honest in both
    directions without ever stalling a request on a full-table pass:

    * growth is **incremental** — when the cache population crosses the
      QF's max-load point the driver opens an
      ``filters.incremental_resize`` migration, and each subsequent
      request batch moves one bounded ``chunk`` of quotient runs into
      the doubled table (membership stays exact throughout; the p99
      insert latency during growth is the chunk cost, not the table
      cost — see ``benchmarks/bench_incremental.py``);
    * after heavy eviction the low watermark shrinks the table back
      (each halving *improves* the fp rate by returning a remainder
      bit), with hysteresis so a cache oscillating around a boundary
      never thrashes between grow and shrink.

    Each doubling halves the remaining remainder bits, i.e. doubles the
    FP (wasted remote probe) rate, so provision ``r`` with the headroom
    you care about.

    ``family="steady_qf"`` swaps in the steady-state QF: every insert is
    O(buffer) with LSM-style background settle ticks folding the buffer
    into the table, so request-path p99 stays bounded even between
    growth episodes (the flat QF's in-place run rewrites are the other
    latency tail; see ``benchmarks/bench_steady_state.py``).

    ``family="cascade"`` backs the filter with the cascade instead (Q0
    in RAM, cold levels on flash) for caches whose population outgrows
    a flat RAM table; ``frozen_below=k`` additionally demotes cascade
    levels at depth >= k to the binary-fuse cold tier — ~20-30% smaller
    cold levels at a fixed 3-read probe, but frozen caches cannot
    ``evict`` (``filters.UnsupportedOpError``; check ``can_evict``):
    demoted prefixes age out only through merges/rebuilds.
    """

    def __init__(self, q: int = 16, r: int = 14, seed: int = 0,
                 backend: str = "reference", auto_scale: bool = True,
                 chunk: int = 2048, family: str = "qf",
                 frozen_below: int | None = None, **family_spec):
        if family in ("qf", "steady_qf"):
            if frozen_below is not None:
                raise ValueError("frozen_below needs family='cascade'")
            if family == "steady_qf":
                # steady-state ingest: O(buffer) insert per request batch
                # with background settle ticks — bounded p99 even while
                # the cache churns (see benchmarks/bench_steady_state.py)
                family_spec.setdefault("chunk", chunk)
            self.cfg, self.state = filters.make(
                family, q=q, r=r, seed=seed, backend=backend, **family_spec
            )
        elif family == "cascade":
            family_spec.setdefault("ram_q", q)
            family_spec.setdefault("p", q + r)
            if frozen_below is not None:
                family_spec["frozen_below"] = frozen_below
            self.cfg, self.state = filters.make(
                "cascade", seed=seed, backend=backend, **family_spec
            )
        else:
            raise ValueError(
                f"family must be 'qf', 'steady_qf' or 'cascade', got {family!r}"
            )
        self.auto_scale = auto_scale
        self.chunk = chunk

    @property
    def can_evict(self) -> bool:
        """False when the backing filter is frozen-tier (no deletes)."""
        return filters.supports(self.cfg, "delete")

    @staticmethod
    def _digest(prompts: np.ndarray) -> jnp.ndarray:
        return jnp.asarray(
            [fold_bytes(np.asarray(p, np.int32).tobytes()) for p in prompts],
            jnp.uint32,
        )

    def check_and_insert(self, prompts: np.ndarray) -> np.ndarray:
        """Membership for each prompt; then insert the misses."""
        keys = self._digest(prompts)
        hit = filters.contains(self.cfg, self.state, keys)
        # intra-batch duplicates: mark later copies as hits, device-side
        # (stable sort + adjacent-equal, scattered back through the
        # permutation) — the filter probe and the dup pass fuse into one
        # program instead of a per-key host loop syncing per digest
        order = jnp.argsort(keys)  # jax sorts are stable: first copy wins
        sk = keys[order]
        dup_sorted = jnp.zeros(keys.shape, bool).at[1:].set(sk[1:] == sk[:-1])
        hit = hit | jnp.zeros(keys.shape, bool).at[order].set(dup_sorted)
        hit = np.asarray(hit)  # single batched transfer: the caller's mask
        misses = keys[jnp.asarray(~hit)]
        if misses.shape[0]:
            if self.auto_scale:
                self.cfg, self.state = filters.auto_scale(
                    self.cfg, self.state, misses, chunk=self.chunk
                )
            else:
                self.state = filters.insert(self.cfg, self.state, misses)
        return hit

    def evict(self, prompts: np.ndarray) -> None:
        keys = self._digest(prompts)
        # deletes are not defined mid-migration: collapse it first (the
        # host-level settle; eviction is already off the hot path)
        self.cfg, self.state = filters.settle(self.cfg, self.state)
        self.state = filters.delete(self.cfg, self.state, keys)
        if self.auto_scale and bool(filters.needs_shrink(self.cfg, self.state)):
            self.cfg, self.state = filters.shrink(self.cfg, self.state)

    @property
    def load(self) -> float:
        s = filters.stats(self.cfg, self.state)
        return float(s["load"] if "load" in s else s["q0_load"])
