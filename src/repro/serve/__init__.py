from . import serve_step  # noqa
