"""Serving steps: prefill + single-token decode, mesh-shardable."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import model


def cache_pspecs(cfg, rules, cache_tree):
    """PartitionSpecs for a decode cache: batch over DP, kv heads or
    head_dim over TP; recurrent states batch-sharded."""

    def spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = leaf.ndim

        def tail(axes):
            return rules.spec(
                (None,) * (nd - len(axes)) + axes, tuple(leaf.shape)
            )

        if name in ("k", "v"):
            return tail(("batch", None, "kv_heads", "head_dim"))
        if name == "c_kv" or name == "k_rope":
            return tail(("batch", None, None))
        if name == "kpos":
            return tail(("batch", None))
        if name == "conv":
            return tail(("batch", None, None))
        if name == "state":
            return tail(("batch",) + (None,) * (min(nd, 4) - 1))
        if name == "pos":
            return P()
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def make_serve_step(cfg):
    def serve_step(params, cache, tokens):
        with_rules_logits, new_cache = model.decode_step(params, cfg, cache, tokens)
        return with_rules_logits, new_cache

    return serve_step


def make_prefill_step(cfg):
    def prefill_step(params, batch):
        return model.prefill(params, cfg, batch, remat=True, headroom=0)

    return prefill_step


def sample_greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_temperature(logits, key, temperature: float = 0.8):
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)
