"""Mixture-of-experts FFN with capacity-based sort dispatch.

Tokens pick top-k experts; (token, expert) pairs are sorted by expert
and gathered into a dense capacity buffer, each expert runs a batched
matmul, and results scatter back weighted.

Dispatch is **vmapped over the batch dim** so every sort/gather stays
local to the data shard that owns the row — the only cross-device
traffic is the (batch-shard -> expert-shard) all-to-all GSPMD inserts
around the expert einsum when experts map to the "model" axis (true EP,
e.g. deepseek-v2's 64 experts / 16), or none at all in TP-MoE mode
(grok's 8 experts: replicated experts, ffn dim sharded).  A global
dispatch would materialize (B*S*k, d) gathers on every device — at
256x4096 tokens that is tens of GiB; the per-row form is ~MBs.

Shared experts (DeepSeek-V2) run densely as one fused MLP.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constrain
from .layers import _act


def _dispatch_row(x_row, top_idx, top_w, n_experts: int, capacity: int):
    """Dispatch one row: x_row (S, d), top_idx/top_w (S, k).

    Returns (xe (E, C, d), combine metadata)."""
    S, k = top_idx.shape
    d = x_row.shape[-1]
    flat_e = top_idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)
    flat_w = top_w.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, st_, sw = flat_e[order], flat_t[order], flat_w[order]
    start = jnp.searchsorted(se, jnp.arange(n_experts, dtype=jnp.int32))
    rank = jnp.arange(S * k, dtype=jnp.int32) - start[se]
    keep = rank < capacity
    slot = jnp.where(keep, se * capacity + rank, jnp.int32(2**31 - 1))

    xe = (
        jnp.zeros((n_experts * capacity, d), x_row.dtype)
        .at[slot]
        .set(x_row[st_], mode="drop")
        .reshape(n_experts, capacity, d)
    )
    return xe, (slot, st_, sw, keep)


def _combine_row(ye, meta, S: int):
    slot, st_, sw, keep = meta
    E, C, d = ye.shape
    yf = ye.reshape(E * C, d)
    contrib = yf[jnp.clip(slot, 0, E * C - 1)] * sw[:, None].astype(yf.dtype)
    contrib = jnp.where(keep[:, None], contrib, 0)
    return jnp.zeros((S, d), ye.dtype).at[st_].add(contrib)


def moe_ffn(p, x, cfg):
    """x: (B, S, d) -> (B, S, d), plus load-balance aux loss."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k

    # explicit SP boundary: gather the sequence dim ONCE here.  The
    # dispatch gathers/scatters by data-dependent indices along S;
    # left seq-sharded, GSPMD re-materializes (all-gathers) x for every
    # such op — ~800 GiB/step on deepseek-v2 — instead of once.
    x = constrain(x, "batch", None, "embed")

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(S * k / E * cfg.capacity_factor))
    capacity = min(capacity + (-capacity) % 8, S * k)

    xe, meta = jax.vmap(
        lambda xr, ti, tw: _dispatch_row(xr, ti, tw, E, capacity)
    )(x, top_idx.astype(jnp.int32), top_w)
    # (B, E, C, d): batch stays on the data axis, experts go to "model"
    xe = constrain(xe, "batch", "experts", None, "embed")

    if "wg" in p:
        g = jnp.einsum("becd,edf->becf", xe, p["wg"])
        h = jnp.einsum("becd,edf->becf", xe, p["wi"])
        h = _act(cfg.mlp_kind, g) * h
    else:
        h = _act(cfg.mlp_kind, jnp.einsum("becd,edf->becf", xe, p["wi"]))
    h = constrain(h, "batch", "experts", None, "expert_ffn")
    ye = jnp.einsum("becf,efd->becd", h, p["wo"])
    ye = constrain(ye, "batch", "experts", None, "embed")

    y = jax.vmap(lambda yr, mt: _combine_row(yr, mt, S))(ye, meta)
    y = constrain(y, "batch", "seq", "embed")  # back to SP for the residual

    if cfg.n_shared_experts:
        hs = jnp.einsum("bsd,df->bsf", x, p["shared_wi"])
        if "shared_wg" in p:
            gs = jnp.einsum("bsd,df->bsf", x, p["shared_wg"])
            hs = _act(cfg.mlp_kind, gs) * hs
        else:
            hs = _act(cfg.mlp_kind, hs)
        y = y + jnp.einsum("bsf,fd->bsd", hs, p["shared_wo"])

    # load-balance aux (Switch-style): E * sum_e f_e * p_e
    frac = jnp.mean(
        jax.nn.one_hot(top_idx, E, dtype=jnp.float32).sum(2), axis=(0, 1)
    ) / k
    pmean = probs.mean((0, 1))
    aux = E * jnp.sum(frac * pmean)
    return y, aux
