"""Model assembly: parameter schema, forward, loss, prefill, decode.

Public API used by the launcher, tests and benchmarks:

  schema(cfg)                  -> Param pytree (single source of truth)
  init(cfg, seed)              -> random params (smoke / real training)
  abstract(cfg)                -> ShapeDtypeStruct params (dry-run)
  partition_specs(cfg, rules)  -> PartitionSpecs mirroring params
  loss_fn(params, cfg, batch)  -> (loss, metrics)
  prefill(params, cfg, batch)  -> (logits_last, cache)
  decode_step(params, cfg, cache, tokens) -> (logits, cache)
  init_cache(cfg, batch, ctx)  -> zeroed decode cache (pos = ctx)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding import constrain
from . import schema as S
from .layers import embed_tokens, unembed
from .transformer import (
    apply_unit,
    layer_kinds,
    norm,
    scan_units,
    split_layers,
    unit_pattern,
)

Param = S.Param


# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------


def _norm_schema(cfg, dim=None):
    d = dim or cfg.d_model
    if cfg.is_encoder_decoder:  # whisper: LayerNorm
        return {
            "scale": Param((d,), ("embed",), "ones"),
            "bias": Param((d,), ("embed",), "zeros"),
        }
    return {
        "scale": Param((d,), ("embed",), "ones" if not cfg.embed_scale else "zeros")
    }


def _attn_schema(cfg):
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    out = {
        "wq": Param((d, H, Dh), ("embed", "heads", "head_dim")),
        "wk": Param((d, KV, Dh), ("embed", "kv_heads", "head_dim")),
        "wv": Param((d, KV, Dh), ("embed", "kv_heads", "head_dim")),
        "wo": Param((H, Dh, d), ("heads", "head_dim", "embed"), scale=0.02),
    }
    if cfg.qk_norm:
        out["q_norm"] = Param((Dh,), (None,), "ones")
        out["k_norm"] = Param((Dh,), (None,), "ones")
    return out


def _mla_schema(cfg):
    d, H = cfg.d_model, cfg.n_heads
    nope, rdim, vdim, lora = (
        cfg.qk_nope_dim,
        cfg.qk_rope_dim,
        cfg.v_head_dim,
        cfg.kv_lora_rank,
    )
    return {
        "wq": Param((d, H, nope + rdim), ("embed", "heads", "qk_dim")),
        "w_dkv": Param((d, lora + rdim), ("embed", None)),
        "kv_norm": Param((lora,), (None,), "ones"),
        "w_uk": Param((lora, H, nope), (None, "heads", "qk_dim")),
        "w_uv": Param((lora, H, vdim), (None, "heads", "qk_dim")),
        "wo": Param((H, vdim, d), ("heads", "qk_dim", "embed"), scale=0.02),
    }


def _mlp_schema(cfg, width=None):
    d, ff = cfg.d_model, width or cfg.d_ff
    out = {
        "wi": Param((d, ff), ("embed", "ffn")),
        "wo": Param((ff, d), ("ffn", "embed")),
    }
    if cfg.mlp_kind in ("swiglu", "geglu"):
        out["wg"] = Param((d, ff), ("embed", "ffn"))
    return out


def _moe_schema(cfg):
    d, E = cfg.d_model, cfg.n_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    out = {
        "router": Param((d, E), ("embed", None), scale=0.02),
        "wi": Param((E, d, ff), ("experts", "embed", "expert_ffn")),
        "wo": Param((E, ff, d), ("experts", "expert_ffn", "embed")),
    }
    if cfg.mlp_kind in ("swiglu", "geglu"):
        out["wg"] = Param((E, d, ff), ("experts", "embed", "expert_ffn"))
    if cfg.n_shared_experts:
        w = cfg.n_shared_experts * ff
        out["shared_wi"] = Param((d, w), ("embed", "ffn"))
        out["shared_wo"] = Param((w, d), ("ffn", "embed"))
        if cfg.mlp_kind in ("swiglu", "geglu"):
            out["shared_wg"] = Param((d, w), ("embed", "ffn"))
    return out


def _ssm_schema(cfg):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    G, N, K = cfg.ssm_n_groups, cfg.ssm_d_state, cfg.ssm_d_conv
    H = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * G * N
    return {
        "in_proj": Param((d, 2 * d_in + 2 * G * N + H), ("embed", "ssm_inner")),
        "conv_w": Param((K, conv_dim), (None, "ssm_inner"), scale=0.2),
        "conv_b": Param((conv_dim,), ("ssm_inner",), "zeros"),
        "A_log": Param((H,), (None,), "const", scale=1.39),  # A ~ -4
        "dt_bias": Param((H,), (None,), "const", scale=-4.6),  # dt ~ 0.01
        "D": Param((H,), (None,), "ones"),
        "out_norm": Param((d_in,), ("ssm_inner",), "ones"),
        "out_proj": Param((d_in, d), ("ssm_inner", "embed")),
    }


def _rec_schema(cfg):
    d = cfg.d_model
    w = cfg.lru_width or d
    return {
        "w_gate": Param((d, w), ("embed", "lru")),
        "w_rec": Param((d, w), ("embed", "lru")),
        "conv_w": Param((4, w), (None, "lru"), scale=0.2),
        "conv_b": Param((w,), ("lru",), "zeros"),
        # column-parallel gates: replicate-in, shard-out — turns the
        # per-gate f32 all-reduce into one bf16 all-gather of the input
        "w_a": Param((w, w), (None, "lru")),
        "b_a": Param((w,), ("lru",), "zeros"),
        "w_x": Param((w, w), (None, "lru")),
        "b_x": Param((w,), ("lru",), "zeros"),
        "lam": Param((w,), (None,), "const", scale=1.0),
        "w_out": Param((w, d), ("lru", "embed")),
    }


def _subblock_schema(cfg, kind: str, moe_layer: bool):
    if kind == "ssm":
        return {"norm": _norm_schema(cfg), "ssm": _ssm_schema(cfg)}
    if kind == "rec":
        return {
            "norm": _norm_schema(cfg),
            "rec": _rec_schema(cfg),
            "mlp_norm": _norm_schema(cfg),
            "mlp": _mlp_schema(cfg),
        }
    if kind == "xattn":
        return {
            "norm1": _norm_schema(cfg),
            "self_attn": _attn_schema(cfg),
            "norm2": _norm_schema(cfg),
            "cross_attn": _attn_schema(cfg),
            "norm3": _norm_schema(cfg),
            "mlp": _mlp_schema(cfg),
        }
    attn = _mla_schema(cfg) if cfg.attn_kind == "mla" else _attn_schema(cfg)
    out = {"norm": _norm_schema(cfg), "attn": attn, "mlp_norm": _norm_schema(cfg)}
    if moe_layer:
        out["moe"] = _moe_schema(cfg)
    else:
        out["mlp"] = _mlp_schema(cfg)
    return out


def _unit_schema(cfg, pat, moe_flags):
    return {
        f"b{i}": _subblock_schema(cfg, k, moe_flags[i]) for i, k in enumerate(pat)
    }


def _stack(schema_tree, n: int):
    return jax.tree_util.tree_map(
        lambda p: Param((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale, p.dtype),
        schema_tree,
        is_leaf=S.is_param,
    )


def moe_flags_for(cfg, pat) -> tuple:
    return tuple(cfg.is_moe for _ in pat)


def schema(cfg) -> dict:
    d, V = cfg.d_model, cfg.vocab_size
    pat = unit_pattern(cfg)
    prefix, n_units, tail = split_layers(cfg)
    flags = moe_flags_for(cfg, pat)

    out: dict[str, Any] = {
        "tok_embed": Param((V, d), ("vocab", "embed"), "normal"),
        "final_norm": _norm_schema(cfg),
        "layers": _stack(_unit_schema(cfg, pat, flags), n_units),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = Param((d, V), ("embed", "vocab"))
    if cfg.rope == "learned":
        out["pos_embed"] = Param((cfg.max_seq, d), (None, "embed"), "normal")
    for i in range(prefix):  # unscanned leading dense layers (dsv2)
        out[f"prefix_{i}"] = _subblock_schema(cfg, layer_kinds(cfg)[i], False)
    for i, k in enumerate(tail):  # remainder layers (recurrentgemma 38 % 3)
        out[f"tail_{i}"] = _subblock_schema(cfg, k, cfg.is_moe)
    if cfg.is_encoder_decoder:
        enc_unit = {
            "b0": {
                "norm1": _norm_schema(cfg),
                "self_attn": _attn_schema(cfg),
                "norm3": _norm_schema(cfg),
                "mlp": _mlp_schema(cfg),
            }
        }
        out["encoder"] = {
            "pos_embed": Param((cfg.encoder_seq, d), (None, "embed"), "normal"),
            "layers": _stack(enc_unit, cfg.encoder_layers),
            "final_norm": _norm_schema(cfg),
        }
    return out


def init(cfg, seed: int = 0):
    return S.init_params(schema(cfg), jax.random.PRNGKey(seed), cfg.param_dtype)


def abstract(cfg):
    return S.abstract_params(schema(cfg), cfg.param_dtype)


def partition_specs(cfg, rules):
    return S.param_specs(schema(cfg), rules)


def partition_pspecs(cfg, rules):
    return S.param_pspecs(schema(cfg), rules)


# ---------------------------------------------------------------------------
# Encoder (whisper stub-frontend)
# ---------------------------------------------------------------------------


def _encode(params, cfg, frames, remat=True):
    """frames: (B, enc_seq, d) — precomputed frame embeddings (stub)."""
    enc = params["encoder"]
    x = frames + enc["pos_embed"][None, : frames.shape[1], :].astype(frames.dtype)
    pos = jnp.broadcast_to(
        jnp.arange(frames.shape[1], dtype=jnp.int32)[None, :], frames.shape[:2]
    )

    def body(carry, lp):
        x = carry
        p = lp["b0"]
        from .attention import gqa_attention
        from .layers import mlp as _mlp

        h, _, _ = gqa_attention(
            p["self_attn"], norm(p["norm1"], x, cfg), cfg, pos,
            causal=False, use_rope=False,
        )
        x = x + h
        x = x + _mlp(p["mlp"], norm(p["norm3"], x, cfg), cfg.mlp_kind)
        return x, None

    fn = (
        jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        if remat
        else body
    )
    x, _ = jax.lax.scan(fn, x, enc["layers"])
    return norm(enc["final_norm"], x, cfg)


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def _embed_in(params, cfg, tokens):
    x = embed_tokens(
        params["tok_embed"], tokens, cfg.embed_scale, cfg.d_model
    ).astype(jnp.dtype(cfg.act_dtype))
    if cfg.rope == "learned":
        pos = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        x = x + params["pos_embed"][pos][None].astype(x.dtype)
    return x


def _apply_stack(params, cfg, x, positions, *, mode, cache=None, enc_out=None,
                 mrope_positions=None, remat=True, decode_pos=None):
    """prefix layers -> scanned units -> tail layers."""
    pat = unit_pattern(cfg)
    prefix, n_units, tail = split_layers(cfg)
    flags = moe_flags_for(cfg, pat)
    aux_total = jnp.zeros((), jnp.float32)
    caches, collected = {}, {}

    for i in range(prefix):
        x, nc, col, aux = apply_unit(
            (layer_kinds(cfg)[i],), {"b0": params[f"prefix_{i}"]}, x, cfg,
            positions, mode=mode, enc_out=enc_out,
            cache=None if cache is None else {"b0": cache[f"prefix_{i}"]},
            mrope_positions=mrope_positions, moe_flags=(False,),
            decode_pos=decode_pos,
        )
        aux_total += aux
        if nc is not None:
            caches[f"prefix_{i}"] = nc["b0"]
        if col is not None:
            collected[f"prefix_{i}"] = col["b0"]

    x, sc, scol, aux = scan_units(
        pat, params["layers"], x, cfg, positions, mode=mode,
        cache=None if cache is None else cache["layers"],
        enc_out=enc_out, mrope_positions=mrope_positions,
        moe_flags=flags, remat=remat, decode_pos=decode_pos,
    )
    aux_total += aux
    if sc is not None:
        caches["layers"] = sc
    if scol is not None:
        collected["layers"] = scol

    for i, k in enumerate(tail):
        x, nc, col, aux = apply_unit(
            (k,), {"b0": params[f"tail_{i}"]}, x, cfg, positions, mode=mode,
            cache=None if cache is None else {"b0": cache[f"tail_{i}"]},
            enc_out=enc_out, mrope_positions=mrope_positions,
            moe_flags=(cfg.is_moe,), decode_pos=decode_pos,
        )
        aux_total += aux
        if nc is not None:
            caches[f"tail_{i}"] = nc["b0"]
        if col is not None:
            collected[f"tail_{i}"] = col["b0"]
    return x, caches, collected, aux_total


def forward(params, cfg, batch, *, mode="train", remat=True):
    """batch: dict(tokens (B,S) [, frames, mrope_positions]).

    Returns (logits, collected, aux)."""
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    x = _embed_in(params, cfg, tokens)
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None, :], (B, Sq))
    mrope_positions = batch.get("mrope_positions")
    if cfg.rope == "mrope" and mrope_positions is None:
        mrope_positions = jnp.broadcast_to(positions[None], (3, B, Sq))

    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, cfg, batch["frames"], remat=remat)

    x, _, collected, aux = _apply_stack(
        params, cfg, x, positions, mode=mode, enc_out=enc_out,
        mrope_positions=mrope_positions, remat=remat,
    )
    x = norm(params["final_norm"], x, cfg)
    logits = unembed(params, x, cfg.tie_embeddings)
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, collected, aux


def _streamed_xent(params, cfg, x, targets, chunk: int = 256):
    """Chunked softmax cross-entropy over the sequence dim.

    The full (B, S, V) f32 logits tensor is the single largest train
    buffer (gemma: 256k vocab -> 17 GB/step global).  Computing the
    unembed + logsumexp per S-chunk under jax.checkpoint keeps only one
    chunk's logits live in either pass.  Returns (sum_nll, n_tokens)."""
    B, S, d = x.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, d)
    tc = targets.reshape(B, nc, chunk)

    def one(args):
        xi, ti = args  # (B, chunk, d), (B, chunk)
        logits = unembed(params, xi, cfg.tie_embeddings).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(ti, 0)[..., None], axis=-1
        )[..., 0]
        mask = (ti >= 0).astype(jnp.float32)
        return jnp.sum((logz - tgt) * mask), jnp.sum(mask)

    one = jax.checkpoint(one, policy=jax.checkpoint_policies.nothing_saveable)
    nll, ntok = jax.lax.map(
        one, (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(tc, 1, 0))
    )
    return jnp.sum(nll), jnp.sum(ntok)


def loss_fn(params, cfg, batch, *, remat=True, aux_weight=0.01):
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    x = _embed_in(params, cfg, tokens)
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None, :], (B, Sq))
    mrope_positions = batch.get("mrope_positions")
    if cfg.rope == "mrope" and mrope_positions is None:
        mrope_positions = jnp.broadcast_to(positions[None], (3, B, Sq))
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, cfg, batch["frames"], remat=remat)
    x, _, _, aux = _apply_stack(
        params, cfg, x, positions, mode="train", enc_out=enc_out,
        mrope_positions=mrope_positions, remat=remat,
    )
    x = norm(params["final_norm"], x, cfg)
    nll, ntok = _streamed_xent(params, cfg, x, batch["targets"])
    loss = nll / jnp.maximum(ntok, 1.0)
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux, "tokens": ntok}


# ---------------------------------------------------------------------------
# Decode: cache init, prefill, single-token step
# ---------------------------------------------------------------------------


def _subblock_cache(cfg, kind: str, B: int, ctx: int, dtype):
    """Zeroed cache for one sub-block."""
    dt = jnp.dtype(dtype)
    if kind == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        G, N, K = cfg.ssm_n_groups, cfg.ssm_d_state, cfg.ssm_d_conv
        H = d_in // cfg.ssm_head_dim
        conv_dim = d_in + 2 * G * N
        return {
            "ssm": {
                "conv": jnp.zeros((B, K - 1, conv_dim), dt),
                "state": jnp.zeros((B, H, cfg.ssm_head_dim, N), dt),
            }
        }
    if kind == "rec":
        w = cfg.lru_width or cfg.d_model
        return {
            "rec": {
                "conv": jnp.zeros((B, 3, w), dt),
                "state": jnp.zeros((B, w), dt),
            }
        }
    if kind == "xattn":
        KV, Dh = cfg.n_kv_heads, cfg.head_dim
        return {
            "self": {
                "k": jnp.zeros((B, ctx, KV, Dh), dt),
                "v": jnp.zeros((B, ctx, KV, Dh), dt),
                "kpos": jnp.full((B, ctx), -1, jnp.int32),
            },
            "cross": {
                "k": jnp.zeros((B, cfg.encoder_seq, KV, Dh), dt),
                "v": jnp.zeros((B, cfg.encoder_seq, KV, Dh), dt),
                "kpos": jnp.zeros((B, cfg.encoder_seq), jnp.int32),
            },
        }
    # attn
    length = min(ctx, cfg.attn_window) if cfg.attn_window else ctx
    if cfg.attn_kind == "mla":
        return {
            "attn": {
                "c_kv": jnp.zeros((B, length, cfg.kv_lora_rank), dt),
                "k_rope": jnp.zeros((B, length, cfg.qk_rope_dim), dt),
                "kpos": jnp.full((B, length), -1, jnp.int32),
            }
        }
    KV, Dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "attn": {
            "k": jnp.zeros((B, length, KV, Dh), dt),
            "v": jnp.zeros((B, length, KV, Dh), dt),
            "kpos": jnp.full((B, length), -1, jnp.int32),
        }
    }


def init_cache(cfg, B: int, ctx: int, dtype=None):
    dt = dtype or cfg.act_dtype
    pat = unit_pattern(cfg)
    prefix, n_units, tail = split_layers(cfg)
    kinds = layer_kinds(cfg)

    def stack_leaf(n):
        return lambda leaf: jnp.broadcast_to(leaf[None], (n,) + leaf.shape)

    unit = {f"b{i}": _subblock_cache(cfg, k, B, ctx, dt) for i, k in enumerate(pat)}
    cache: dict[str, Any] = {
        "layers": jax.tree_util.tree_map(stack_leaf(n_units), unit),
        "pos": jnp.asarray(0, jnp.int32),
    }
    for i in range(prefix):
        cache[f"prefix_{i}"] = _subblock_cache(cfg, kinds[i], B, ctx, dt)
    for i, k in enumerate(tail):
        cache[f"tail_{i}"] = _subblock_cache(cfg, k, B, ctx, dt)
    return cache


def prefill(params, cfg, batch, *, remat=True, headroom: int = 128):
    """Full-sequence forward that also fills a decode cache.

    ``headroom`` extra KV slots let decoding continue past the prompt
    without wrapping onto cached context."""
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    logits, collected, _ = forward(params, cfg, batch, mode="prefill", remat=remat)
    cache = init_cache(cfg, B, Sq + headroom, cfg.act_dtype)
    cache = _fill_cache_from_collected(cfg, cache, collected, batch, params, Sq)
    cache["pos"] = jnp.asarray(Sq, jnp.int32)
    return logits[:, -1], cache


def _ring_gather(kv, S, length):
    """Place (B, S, ...) K/V into a length-L ring keyed by p % L.

    Slot j holds the latest position p < S with p % L == j (or is empty
    when L >= S and j >= S). Returns (cache_kv, kpos)."""
    if length >= S:
        padding = [(0, 0), (0, length - S)] + [(0, 0)] * (kv.ndim - 2)
        out = jnp.pad(kv, padding)
        idx = jnp.concatenate(
            [jnp.arange(S, dtype=jnp.int32), jnp.full((length - S,), -1, jnp.int32)]
        )
        return out, idx
    offs = (jnp.arange(length) - S) % length
    idx = (S - length + offs).astype(jnp.int32)
    return jnp.take(kv, idx, axis=1), idx


def _fill_unit_cache(cfg, kind, cache_b, col_b, S, positions):
    if kind in ("ssm", "rec"):
        cache_b[kind]["state"] = col_b[kind]["state"].astype(
            cache_b[kind]["state"].dtype
        )
        cache_b[kind]["conv"] = col_b[kind]["conv"].astype(
            cache_b[kind]["conv"].dtype
        )
        return cache_b
    key = "self_kv" if kind == "xattn" else "kv"
    sub = "self" if kind == "xattn" else "attn"
    if cfg.attn_kind == "mla" and kind == "attn":
        c_kv, k_rope = col_b["kv"]
        length = cache_b[sub]["c_kv"].shape[-2]
        ck, idx = _ring_gather(c_kv, S, length)
        cr, _ = _ring_gather(k_rope, S, length)
        cache_b[sub]["c_kv"] = ck
        cache_b[sub]["k_rope"] = cr
        cache_b[sub]["kpos"] = jnp.broadcast_to(idx[None], ck.shape[:2]).astype(
            jnp.int32
        )
        return cache_b
    k, v = col_b[key]
    length = cache_b[sub]["k"].shape[-3]
    ck, idx = _ring_gather(k, S, length)
    cv, _ = _ring_gather(v, S, length)
    cache_b[sub]["k"] = ck
    cache_b[sub]["v"] = cv
    cache_b[sub]["kpos"] = jnp.broadcast_to(idx[None], ck.shape[:2]).astype(jnp.int32)
    return cache_b


def _fill_cache_from_collected(cfg, cache, collected, batch, params, S):
    pat = unit_pattern(cfg)
    prefix, n_units, tail = split_layers(cfg)
    kinds = layer_kinds(cfg)
    for i in range(prefix):
        if f"prefix_{i}" in collected:
            cache[f"prefix_{i}"] = _fill_unit_cache(
                cfg, kinds[i], cache[f"prefix_{i}"], collected[f"prefix_{i}"], S, None
            )
    if "layers" in collected:
        for i, kind in enumerate(pat):
            key = f"b{i}"
            col = collected["layers"][key]  # leaves stacked (n_units, ...)
            cb = cache["layers"][key]
            # vmap the fill over the stacked layer axis
            filled = jax.vmap(
                lambda c, co: _fill_unit_cache(cfg, kind, c, co, S, None)
            )({k: v for k, v in cb.items()} if isinstance(cb, dict) else cb, col)
            cache["layers"][key] = filled
    for i, k in enumerate(tail):
        if f"tail_{i}" in collected:
            cache[f"tail_{i}"] = _fill_unit_cache(
                cfg, k, cache[f"tail_{i}"], collected[f"tail_{i}"], S, None
            )
    if cfg.is_encoder_decoder:
        # cross K/V from the encoder output, computed once
        enc_out = _encode(params, cfg, batch["frames"], remat=False)
        def fill_cross(cb, p_cross):
            from .attention import gqa_attention  # noqa

            k = jnp.einsum("bsd,dhk->bshk", enc_out, p_cross["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc_out, p_cross["wv"])
            cb["cross"]["k"] = k.astype(cb["cross"]["k"].dtype)
            cb["cross"]["v"] = v.astype(cb["cross"]["v"].dtype)
            cb["cross"]["kpos"] = jnp.broadcast_to(
                jnp.arange(k.shape[1], dtype=jnp.int32)[None], k.shape[:2]
            )
            return cb

        cache["layers"]["b0"] = jax.vmap(
            fill_cross, in_axes=(0, 0)
        )(cache["layers"]["b0"], params["layers"]["b0"]["cross_attn"])
    return cache


def _write_delta(cfg, kind, sub: dict, delta: dict, pos):
    """Persist one sub-block's decode delta with aliased in-place
    updates (leaves may carry a leading stacked-layer dim)."""
    key = "self" if kind == "xattn" else "attn"
    tgt = dict(sub[key])
    if "c_kv" in tgt:  # MLA latent cache
        ring = tgt["c_kv"].shape[-2]
        slot = pos % ring
        lead = tgt["c_kv"].ndim - 3
        z = (0,) * lead
        tgt["c_kv"] = jax.lax.dynamic_update_slice(
            tgt["c_kv"], delta["c_kv"].astype(tgt["c_kv"].dtype), z + (0, slot, 0)
        )
        tgt["k_rope"] = jax.lax.dynamic_update_slice(
            tgt["k_rope"], delta["k_rope"].astype(tgt["k_rope"].dtype), z + (0, slot, 0)
        )
    else:
        ring = tgt["k"].shape[-3]
        slot = pos % ring
        lead = tgt["k"].ndim - 4
        z = (0,) * lead
        tgt["k"] = jax.lax.dynamic_update_slice(
            tgt["k"], delta["k"].astype(tgt["k"].dtype), z + (0, slot, 0, 0)
        )
        tgt["v"] = jax.lax.dynamic_update_slice(
            tgt["v"], delta["v"].astype(tgt["v"].dtype), z + (0, slot, 0, 0)
        )
    kp = tgt["kpos"]
    upd = jnp.full(kp.shape[:-1] + (1,), pos, jnp.int32)
    tgt["kpos"] = jax.lax.dynamic_update_slice(
        kp, upd, (0,) * (kp.ndim - 1) + (slot,)
    )
    out = dict(sub)
    out[key] = tgt
    return out


def decode_step(params, cfg, cache, tokens, *, mrope_positions=None):
    """tokens: (B, 1). Returns (logits (B, V), new_cache).

    Attention layers never write the cache inside the layer scan (see
    attention._attend_decode); their per-layer K/V deltas come back as
    scan outputs and are committed here with one aliased
    dynamic-update-slice per leaf — the donated cache buffer is updated
    in place, no second copy exists."""
    B = tokens.shape[0]
    pos = cache["pos"]
    positions = jnp.full((B, 1), pos, jnp.int32)
    x = embed_tokens(params["tok_embed"], tokens, cfg.embed_scale, cfg.d_model)
    x = x.astype(jnp.dtype(cfg.act_dtype))
    if cfg.rope == "learned":
        x = x + params["pos_embed"][jnp.minimum(pos, cfg.max_seq - 1)][
            None, None
        ].astype(x.dtype)
    if cfg.rope == "mrope" and mrope_positions is None:
        mrope_positions = jnp.broadcast_to(positions[None], (3, B, 1))

    x, caches, collected, _ = _apply_stack(
        params, cfg, x, positions, mode="decode",
        cache=cache, enc_out=None, mrope_positions=mrope_positions,
        remat=False, decode_pos=pos,
    )
    x = norm(params["final_norm"], x, cfg)
    logits = unembed(params, x, cfg.tie_embeddings)[:, 0]

    pat = unit_pattern(cfg)
    prefix, n_units, tail = split_layers(cfg)
    kinds = layer_kinds(cfg)
    new_cache = {k: v for k, v in cache.items()}
    # recurrent/ssm states come back via the cache channel
    for grp, sub in caches.items():
        if grp == "layers":
            merged = dict(new_cache["layers"])
            merged.update(sub)
            new_cache["layers"] = merged
        else:
            new_cache[grp] = sub
    # attention K/V deltas commit here
    if collected:
        for grp, sub in collected.items():
            if grp == "layers":
                merged = dict(new_cache["layers"])
                for i, kind in enumerate(pat):
                    key = f"b{i}"
                    if key in sub and "delta" in sub[key]:
                        merged[key] = _write_delta(
                            cfg, kind, new_cache["layers"][key],
                            sub[key]["delta"], pos,
                        )
                new_cache["layers"] = merged
            else:
                idx = int(grp.split("_")[1])
                kind = kinds[idx] if grp.startswith("prefix") else (
                    tail[idx] if grp.startswith("tail") else "attn"
                )
                if "delta" in sub:
                    new_cache[grp] = _write_delta(
                        cfg, kind, new_cache[grp], sub["delta"], pos
                    )
    new_cache["pos"] = pos + 1
    return logits, new_cache
