from . import attention, layers, model, moe, rglru, schema, ssm, transformer  # noqa
