"""Mamba-2 (SSD — state-space duality) mixer block.

Chunked SSD algorithm [Dao & Gu, arXiv:2405.21060]: the sequence is
split into Q-length chunks; intra-chunk terms are dense (Q x Q) masked
matmuls (MXU-friendly — the whole point of SSD on TPU), inter-chunk
state is a per-chunk associative scan over (decay, state) pairs.

Decode path carries (conv window, ssm state) and is O(1) per token —
this is what makes the long_500k cell tractable for this family.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constrain
from .layers import conv1d_causal, rms_norm


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """xh: (B, S, H, P); dt: (B, S, H); A: (H,) negative;
    Bm/Cm: (B, S, G, N). Returns (y, final_state (B, H, P, N))."""
    B_, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hper = H // G
    nc = S // chunk

    xc = xh.reshape(B_, nc, chunk, H, P)
    dtc = dt.reshape(B_, nc, chunk, H)
    Bc = Bm.reshape(B_, nc, chunk, G, N)
    Cc = Cm.reshape(B_, nc, chunk, G, N)

    dA = dtc * A  # (B, nc, Q, H), negative
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative

    # intra-chunk: scores[b,c,h,i,j] = C_i . B_j * exp(cum_i - cum_j) * dt_j
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)  # (B,nc,G,Q,Q)
    CB = jnp.repeat(CB, hper, axis=2)  # (B,nc,H,Q,Q)
    diff = (
        cum.transpose(0, 1, 3, 2)[..., :, None]
        - cum.transpose(0, 1, 3, 2)[..., None, :]
    )  # (B,nc,H,Q,Q); <= 0 on the causal (lower) triangle since cum is
    # non-increasing — clamp so the masked upper triangle cannot
    # overflow exp and poison gradients through the where.
    decay = jnp.exp(jnp.minimum(diff, 0.0))
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    scores = jnp.where(mask, CB * decay, 0.0) * dtc.transpose(0, 1, 3, 2)[..., None, :]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores.astype(xh.dtype), xc)

    # chunk states: S_c = sum_j exp(cum_end - cum_j) dt_j B_j (x) x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    w = (decay_to_end * dtc).astype(xh.dtype)
    Bh = jnp.repeat(Bc, hper, axis=3).reshape(B_, nc, chunk, H, N) if G != H else Bc
    states = jnp.einsum("bcqhn,bcqhp,bcqh->bchpn", Bh.astype(xh.dtype), xc, w)

    # inter-chunk scan: H_c = exp(sum dA_c) * H_{c-1} + S_c
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))  # (B, nc, H)

    def scan_fn(a, b):
        a_d, a_s = a
        b_d, b_s = b
        return a_d * b_d, a_s * b_d[..., None, None].astype(a_s.dtype) + b_s

    d_sc, s_sc = jax.lax.associative_scan(
        scan_fn, (chunk_decay, states.astype(jnp.float32)), axis=1
    )
    # H_{c-1} entering chunk c
    prev = jnp.concatenate(
        [jnp.zeros_like(s_sc[:, :1]), s_sc[:, :-1]], axis=1
    )  # (B,nc,H,P,N)

    # inter contribution: y_j += exp(cum_j) C_j . H_prev
    Ch = jnp.repeat(Cc, hper, axis=3).reshape(B_, nc, chunk, H, N) if G != H else Cc
    y_inter = jnp.einsum(
        "bcqhn,bchpn->bcqhp", Ch.astype(jnp.float32) * jnp.exp(cum)[..., None], prev
    )
    y = y_intra + y_inter.astype(xh.dtype)
    final_state = s_sc[:, -1].astype(xh.dtype)  # (B,H,P,N)
    return y.reshape(B_, S, H, P), final_state


def ssm_block(p, x, cfg, *, cache=None):
    """Mamba-2 mixer. x: (B, S, d). cache = dict(conv, state) for decode."""
    B, S, d = x.shape
    d_in = cfg.ssm_expand * d
    G, N = cfg.ssm_n_groups, cfg.ssm_d_state
    P = cfg.ssm_head_dim
    H = d_in // P

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    z, xBC, dt = jnp.split(zxbcdt, [d_in, d_in + d_in + 2 * G * N], axis=-1)
    xBC = constrain(xBC, "batch", None, "ssm_inner")

    conv_cache = cache["conv"] if cache is not None else None
    xBC, new_conv = conv1d_causal(xBC, p["conv_w"], p["conv_b"], cache=conv_cache)
    xBC = jax.nn.silu(xBC)

    xh = xBC[..., :d_in].reshape(B, S, H, P)
    Bm = xBC[..., d_in : d_in + G * N].reshape(B, S, G, N)
    Cm = xBC[..., d_in + G * N :].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)

    if cache is None:
        chunk = min(cfg.ssm_chunk, S)
        pad = (-S) % chunk
        if pad:
            # zero-pad to a chunk multiple; dt=0 on padding keeps the
            # recurrence inert (decay 1, update 0) so states are exact
            zf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
            xh_p, Bm_p, Cm_p = zf(xh), zf(Bm), zf(Cm)
            dt_p = jnp.pad(dt, [(0, 0), (0, pad), (0, 0)])
            y, final_state = _ssd_chunked(xh_p, dt_p, A, Bm_p, Cm_p, chunk)
            y = y[:, :S]
        else:
            y, final_state = _ssd_chunked(xh, dt, A, Bm, Cm, chunk)
        new_state = final_state
    else:
        # O(1) decode: h = exp(dt A) h + dt B (x) x ; y = C . h
        h0 = cache["state"]  # (B, H, P, N)
        dt1 = dt[:, 0]  # (B, H)
        dA = jnp.exp(dt1 * A)  # (B, H)
        Bh = jnp.repeat(Bm[:, 0], H // G, axis=1) if G != H else Bm[:, 0]
        upd = jnp.einsum(
            "bhn,bhp,bh->bhpn",
            Bh.astype(jnp.float32),
            xh[:, 0].astype(jnp.float32),
            dt1,
        )
        h1 = h0.astype(jnp.float32) * dA[..., None, None] + upd
        Ch = jnp.repeat(Cm[:, 0], H // G, axis=1) if G != H else Cm[:, 0]
        y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), h1)[:, None]
        y = y.reshape(B, 1, H, P).astype(x.dtype)
        new_state = h1.astype(x.dtype)

    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    new_cache = {"conv": new_conv, "state": new_state} if cache is not None else None
    return out, new_cache, {"state": new_state, "conv": new_conv}
