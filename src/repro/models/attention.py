"""Attention: GQA/MQA/MHA, MLA (DeepSeek-V2), sliding-window, cross-attn.

Two execution paths:
* ``_attend_naive`` — materializes (Sq, Sk) scores; used for short
  sequences and single-token decode.
* ``_attend_chunked`` — flash-style online-softmax over KV chunks with
  the query dimension also chunked; memory O(q_chunk * kv_chunk)
  per program instead of O(S^2).  Pure jnp + lax.scan (TPU-friendly:
  the inner contraction is an MXU matmul per chunk pair).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constrain
from .layers import apply_mrope, apply_rope, rms_norm

NEG_INF = -1e30


def _apply_mask(s, q_pos, k_pos, causal: bool, window: int):
    """Mask scores in place via a fused where.

    Deliberately NOT a precomputed additive bias tensor: a separate
    (Sq, Sk) f32 bias is loop-invariant across layers and XLA's LICM
    hoists it into the scan carry — a catastrophic (B, Sq, Sk) resident
    buffer at 32k context.  An inline iota-compare fuses into the
    softmax and materializes nothing.  s: (B, KV, G, Sq, Sk)."""
    qp = q_pos[:, None, None, :, None]
    kp = k_pos[:, None, None, None, :]
    valid = kp >= 0
    if causal:
        valid &= kp <= qp
    if window:
        valid &= qp - kp < window
    return jnp.where(valid, s, NEG_INF)


def _softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap else x


def _attend_naive(q, k, v, q_pos, k_pos, *, causal, window, softcap, scale):
    # q: (B, Sq, KV, G, Dh), k/v: (B, Sk, KV, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    s = _softcap(s, softcap)
    s = _apply_mask(s, q_pos, k_pos, causal, window)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v)
    return o


def _attend_chunked(
    q, k, v, q_pos, k_pos, *, causal, window, softcap, scale, q_chunk, kv_chunk
):
    B, Sq, KV, G, Dh = q.shape
    Sk = k.shape[1]
    Dv = v.shape[-1]  # may differ from Dh (absorbed MLA: latent values)
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    qc = q.reshape(B, nq, q_chunk, KV, G, Dh)
    qp = q_pos.reshape(B, nq, q_chunk)
    kc = k.reshape(B, nk, kv_chunk, KV, Dh)
    vc = v.reshape(B, nk, kv_chunk, KV, Dv)
    kp = k_pos.reshape(B, nk, kv_chunk)

    def q_block(args):
        qb, qpb = args  # (B, qc, KV, G, Dh), (B, qc)

        def kv_step(carry, xs):
            m, l, acc = carry
            kb, vb, kpb = xs  # (B, kc, KV, Dh), (B, kc)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb).astype(jnp.float32) * scale
            s = _softcap(s, softcap)
            s = _apply_mask(s, qpb, kpb, causal, window)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vb.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (
                jnp.moveaxis(kc, 1, 0),
                jnp.moveaxis(vc, 1, 0),
                jnp.moveaxis(kp, 1, 0),
            ),
        )
        o = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(o, 3, 1).astype(q.dtype)  # (B, qc, KV, G, Dh)

    q_block = jax.checkpoint(
        q_block, policy=jax.checkpoint_policies.nothing_saveable
    )  # bwd re-runs one q-chunk at a time: O(q_chunk) attention residency
    outs = jax.lax.map(
        q_block, (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(qp, 1, 0))
    )  # (nq, B, qc, KV, G, Dv)
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KV, G, Dv)


def attend(
    q, k, v, q_pos, k_pos, *, causal=True, window=0, softcap=0.0,
    q_chunk=512, kv_chunk=1024, chunk_threshold=2048, scale=None,
):
    scale = q.shape[-1] ** -0.5 if scale is None else scale
    Sq, Sk = q.shape[1], k.shape[1]
    if Sq > chunk_threshold and Sq % q_chunk == 0:
        pad = (-Sk) % kv_chunk
        if pad:
            # ragged KV (e.g. whisper's 1500 encoder frames): pad with
            # kpos = -1 slots, which the mask kills — without this the
            # cross-attention silently fell back to the O(Sq*Sk) naive
            # path and dominated whisper's train memory
            zk = [(0, 0), (0, pad)] + [(0, 0)] * (k.ndim - 2)
            k = jnp.pad(k, zk)
            v = jnp.pad(v, zk)
            k_pos = jnp.pad(k_pos, [(0, 0), (0, pad)], constant_values=-1)
        return _attend_chunked(
            q, k, v, q_pos, k_pos, causal=causal, window=window,
            softcap=softcap, scale=scale, q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
    return _attend_naive(
        q, k, v, q_pos, k_pos, causal=causal, window=window,
        softcap=softcap, scale=scale,
    )


def _attend_decode(qg, ck, cv, kpos, k_new, v_new, q_pos, *, window, softcap, scale):
    """Single-token decode over a READ-ONLY cache plus the fresh K/V.

    The naive path writes the token into the cache first and attends
    over the whole buffer — under jit that materializes a second copy
    of the multi-GiB cache inside the layer scan.  Scoring the cache
    (pure read) and the new token separately, then softmaxing over the
    concatenated scores, needs no cache write at all; the caller
    persists the (L, B, 1, KV, Dh) deltas with one aliased
    dynamic-update-slice after the scan.
    """
    s_c = jnp.einsum("bqkgd,bskd->bkgqs", qg, ck).astype(jnp.float32) * scale
    s_n = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_new).astype(jnp.float32) * scale
    s_c = _softcap(s_c, softcap)
    s_n = _softcap(s_n, softcap)
    valid = (kpos >= 0) & (kpos <= q_pos[:, :1])
    if window:
        valid &= q_pos[:, :1] - kpos < window
    s_c = jnp.where(valid[:, None, None, None, :], s_c, NEG_INF)
    s = jnp.concatenate([s_c, s_n], axis=-1)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bkgqs,bskd->bqkgd", w[..., :-1].astype(cv.dtype), cv
    ) + jnp.einsum("bkgqs,bskd->bqkgd", w[..., -1:].astype(v_new.dtype), v_new)
    return o


# ---------------------------------------------------------------------------
# GQA attention layer (covers MHA and MQA as kv_heads extremes)
# ---------------------------------------------------------------------------


def gqa_attention(
    p,
    x,
    cfg,
    positions,
    *,
    causal=True,
    window=0,
    cache=None,
    cache_slot=None,
    kv_from=None,
    is_cross=False,
    use_rope=True,
    mrope_positions=None,
):
    """x: (B, S, d). Returns (out, new_cache, kv) — kv for prefill collection.

    cache: dict(k, v, kpos) for decode; kv_from: encoder output for
    cross-attention (no cache write; cache holds precomputed enc K/V).
    """
    B, S, _ = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KV

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = constrain(q, "batch", None, "heads", "head_dim")
    if is_cross and cache is not None:  # cross-attn decode: cached enc K/V
        k, v = cache["k"], cache["v"]
    elif is_cross:
        k = jnp.einsum("bsd,dhk->bshk", kv_from, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", kv_from, p["wv"])
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    k = constrain(k, "batch", None, "kv_heads", "head_dim")
    v = constrain(v, "batch", None, "kv_heads", "head_dim")

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        if not (is_cross and cache is not None):
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    kv_source_pos = positions
    if use_rope and not is_cross:
        if cfg.rope == "mrope" and mrope_positions is not None:
            q = apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        elif cfg.rope in ("rope", "mrope"):
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    if cache is not None and not is_cross:
        # decode: read-only cache + fresh-token merge; emit the delta
        qg = q.reshape(B, S, KV, G, Dh)
        o = _attend_decode(
            qg, cache["k"], cache["v"], cache["kpos"], k, v, positions,
            window=window, softcap=cfg.logit_softcap, scale=Dh ** -0.5,
        )
        o = o.reshape(B, S, H, Dh)
        o = constrain(o, "batch", None, "heads", "head_dim")
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        return out, None, {"k": k, "v": v}

    if cache is not None:  # cross-attn decode
        k_pos = cache["kpos"]
        q_pos = positions
    else:
        k_pos = kv_source_pos if not is_cross else (
            jnp.broadcast_to(jnp.arange(k.shape[1])[None, :], k.shape[:2])
        )
        q_pos = positions

    kv = (k, v)
    qg = q.reshape(B, S, KV, G, Dh)
    o = attend(
        qg, k, v, q_pos, k_pos,
        causal=causal and not is_cross,
        window=window,
        softcap=cfg.logit_softcap,
    )
    o = o.reshape(B, S, H, Dh)
    o = constrain(o, "batch", None, "heads", "head_dim")
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, None, kv


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent-compressed KV with decode-time absorption
# ---------------------------------------------------------------------------


def mla_attention(p, x, cfg, positions, *, cache=None, cache_slot=None):
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rdim, vdim, lora = (
        cfg.qk_nope_dim,
        cfg.qk_rope_dim,
        cfg.v_head_dim,
        cfg.kv_lora_rank,
    )
    scale = (nope + rdim) ** -0.5

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])  # (B,S,H,nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dk->bsk", x, p["w_dkv"])  # (B,S,lora+rope)
    c_kv = rms_norm(ckv_full[..., :lora], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(
        ckv_full[..., None, lora:], positions, cfg.rope_theta
    )[:, :, 0, :]  # shared single-head rope key

    if cache is not None:
        # decode: score the read-only cached latents + the fresh one;
        # split einsums (latent + rope) avoid any cache-wide concat/copy
        q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, p["w_uk"])  # (B,1,H,lora)
        s_c = (
            jnp.einsum("bshl,btl->bhst", q_lat, cache["c_kv"])
            + jnp.einsum("bshr,btr->bhst", q_rope, cache["k_rope"])
        ).astype(jnp.float32) * scale
        s_n = (
            jnp.einsum("bshl,btl->bhst", q_lat, c_kv)
            + jnp.einsum("bshr,btr->bhst", q_rope, k_rope)
        ).astype(jnp.float32) * scale
        valid = (cache["kpos"] >= 0) & (cache["kpos"] <= positions[:, :1])
        s_c = jnp.where(valid[:, None, None, :], s_c, -1e30)
        w = jax.nn.softmax(jnp.concatenate([s_c, s_n], axis=-1), axis=-1)
        ctx = jnp.einsum(
            "bhst,btl->bshl", w[..., :-1].astype(x.dtype), cache["c_kv"]
        ) + jnp.einsum("bhst,btl->bshl", w[..., -1:].astype(x.dtype), c_kv)
        o = jnp.einsum("bshl,lhv->bshv", ctx, p["w_uv"])
        out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
        return out, None, {"c_kv": c_kv, "k_rope": k_rope}
    k_pos = positions

    # Absorbed MLA == GQA with ONE latent KV head: queries live in
    # (lora + rope) space, keys are concat(c_kv, k_rope), values are the
    # latent c_kv itself.  This reuses the generic (chunked) attend path
    # and is the decode-efficient form (cache = lora + rope per token).
    q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, p["w_uk"])  # (B,S,H,lora)
    q_all = jnp.concatenate([q_lat, q_rope], axis=-1)[:, :, None, :, :]
    # (B, S, KV=1, G=H, lora+rdim)
    q_all = q_all.transpose(0, 1, 2, 3, 4)
    k_all = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None, :]  # KV=1
    v_all = c_kv[:, :, None, :]
    ctx = attend(
        q_all, k_all, v_all, positions, k_pos, causal=True, scale=scale
    )[:, :, 0, :, :]  # (B, S, H, lora)
    o = jnp.einsum("bshl,lhv->bshv", ctx.astype(x.dtype), p["w_uv"])
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
    return out, None, (c_kv, k_rope)
