"""Schema-driven parameters: one source of truth for shapes, sharding
logical axes, and initializers.

``schema(cfg)`` (in model.py) returns a pytree of :class:`Param`
leaves; from it we derive random init (smoke tests / real training),
abstract ShapeDtypeStructs (dry-run — no allocation), and
PartitionSpecs (in_shardings), guaranteed consistent.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class Param(NamedTuple):
    shape: tuple
    axes: tuple  # logical axis names (same rank as shape)
    init: str = "fan_in"  # fan_in | normal | zeros | ones | const
    scale: Optional[float] = None
    dtype: Optional[str] = None  # override cfg.param_dtype


def is_param(x) -> bool:
    return isinstance(x, Param)


def _leaf_dtype(p: Param, default: str):
    return jnp.dtype(p.dtype or default)


def init_params(schema, rng_key, default_dtype: str):
    leaves, treedef = jax.tree_util.tree_flatten(schema, is_leaf=is_param)
    keys = jax.random.split(rng_key, len(leaves))

    def mk(p: Param, k):
        dt = _leaf_dtype(p, default_dtype)
        if p.init == "zeros":
            return jnp.zeros(p.shape, dt)
        if p.init == "ones":
            return jnp.ones(p.shape, dt)
        if p.init == "const":
            return jnp.full(p.shape, p.scale, dt)
        if p.init == "normal":
            return (jax.random.normal(k, p.shape) * (p.scale or 0.02)).astype(dt)
        # fan_in: normal with 1/sqrt(fan_in); fan_in = product of all but
        # the last two axes... use first axis group heuristics: treat the
        # leading "input" dims as fan-in (all dims except the trailing
        # output block is ambiguous for einsum weights; scale by total
        # input size = prod(shape) / prod(last dim block) — we use
        # shape[0] * middle dims conservatively)
        fan_in = p.shape[0] if len(p.shape) >= 1 else 1
        s = p.scale if p.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, p.shape) * s).astype(dt)

    return treedef.unflatten([mk(p, k) for p, k in zip(leaves, keys)])


def abstract_params(schema, default_dtype: str):
    return jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, _leaf_dtype(p, default_dtype)),
        schema,
        is_leaf=is_param,
    )


def param_specs(schema, rules):
    """NamedShardings for every parameter (shape-aware fallback)."""
    return jax.tree_util.tree_map(
        lambda p: rules.sharding(p.axes, p.shape), schema, is_leaf=is_param
    )


def param_pspecs(schema, rules):
    return jax.tree_util.tree_map(
        lambda p: rules.spec(p.axes, p.shape), schema, is_leaf=is_param
    )
