"""RecurrentGemma / Griffin recurrent block (RG-LRU).

Real-Gated Linear Recurrent Unit [arXiv:2402.19427]:
    r_t = sigmoid(W_a x_t + b_a)         (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)         (input gate)
    log a_t = -c * softplus(Lambda) * r_t          (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

A diagonal linear recurrence — prefill uses jax.lax.associative_scan
(log-depth, TPU-native), decode is a one-step update.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constrain
from .layers import conv1d_causal

_C = 8.0


def _rg_lru(p, x, h0=None):
    """x: (B, S, W). Returns (y, h_last)."""
    r = jax.nn.sigmoid(
        jnp.einsum("bsw,wk->bsk", x, p["w_a"]).astype(jnp.float32) + p["b_a"]
    )
    i = jax.nn.sigmoid(
        jnp.einsum("bsw,wk->bsk", x, p["w_x"]).astype(jnp.float32) + p["b_x"]
    )
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r  # (B,S,W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * x.astype(jnp.float32)
    )

    if x.shape[1] == 1 and h0 is not None:  # decode step
        h = a[:, 0] * h0.astype(jnp.float32) + gated[:, 0]
        return h[:, None].astype(x.dtype), h.astype(x.dtype)

    def comb(u, v):
        ua, uh = u
        va, vh = v
        return ua * va, uh * va + vh

    a_sc, h_sc = jax.lax.associative_scan(comb, (a, gated), axis=1)
    if h0 is not None:
        h_sc = h_sc + a_sc * h0[:, None].astype(jnp.float32)
    return h_sc.astype(x.dtype), h_sc[:, -1].astype(x.dtype)


def recurrent_block(p, x, cfg, *, cache=None):
    """Griffin recurrent block: (gelu branch) * (conv -> RG-LRU branch)."""
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, p["w_gate"]), approximate=True
    )
    rec = jnp.einsum("bsd,dw->bsw", x, p["w_rec"])
    rec = constrain(rec, "batch", None, "lru")

    conv_cache = cache["conv"] if cache is not None else None
    rec, new_conv = conv1d_causal(rec, p["conv_w"], p["conv_b"], cache=conv_cache)

    h0 = cache["state"] if cache is not None else None
    rec, h_last = _rg_lru(p, rec, h0)

    y = jnp.einsum("bsw,wd->bsd", gate * rec, p["w_out"])
    new_cache = (
        {"conv": new_conv, "state": h_last} if cache is not None else None
    )
    return y, new_cache, {"state": h_last, "conv": new_conv}
