"""Block composition: pre-norm residual blocks, pattern units, scan.

A model is a stack of *units* (1+ sub-blocks); uniform units are
scanned (compact HLO, FSDP-friendly leading layer axis), remainder /
first-dense layers apply unscanned.  Sub-block kinds:
  attn   — GQA/MLA attention + (MLP | MoE)
  rec    — Griffin recurrent block + MLP
  ssm    — Mamba-2 mixer (no separate MLP)
  xattn  — encoder-decoder block (self + cross attention + MLP)
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding import constrain
from .attention import gqa_attention, mla_attention
from .layers import layer_norm, mlp, rms_norm
from .moe import moe_ffn
from .rglru import recurrent_block
from .ssm import ssm_block


def norm(p, x, cfg):
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    offset = 1.0 if cfg.embed_scale else 0.0  # gemma stores scale-1
    if offset:
        return rms_norm(x, p["scale"], cfg.norm_eps, offset=1.0)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def layer_kinds(cfg) -> list[str]:
    if cfg.family == "ssm":
        return ["ssm"] * cfg.n_layers
    if cfg.block_pattern:
        pat = cfg.block_pattern
        return [pat[i % len(pat)] for i in range(cfg.n_layers)]
    if cfg.is_encoder_decoder:
        return ["xattn"] * cfg.n_layers
    return ["attn"] * cfg.n_layers


def unit_pattern(cfg) -> tuple[str, ...]:
    if cfg.family == "ssm":
        return ("ssm",)
    if cfg.block_pattern:
        return tuple(cfg.block_pattern)
    if cfg.is_encoder_decoder:
        return ("xattn",)
    return ("attn",)


def split_layers(cfg) -> tuple[int, int, list[str]]:
    """(n_prefix_unscanned, n_scanned_units, tail_kinds)."""
    kinds = layer_kinds(cfg)
    pat = unit_pattern(cfg)
    prefix = cfg.first_dense_layers
    body = cfg.n_layers - prefix
    n_units = body // len(pat)
    tail = kinds[prefix + n_units * len(pat) :]
    return prefix, n_units, tail


# ---------------------------------------------------------------------------
# Sub-block application
# ---------------------------------------------------------------------------


def apply_subblock(
    kind: str,
    p: dict,
    x,
    cfg,
    positions,
    *,
    mode: str,  # train | prefill | decode
    cache: Optional[dict] = None,
    enc_out=None,
    mrope_positions=None,
    is_moe_layer: bool = False,
    decode_pos=None,
):
    """Returns (x, new_cache, collected, aux)."""
    aux = jnp.zeros((), jnp.float32)
    collected = None
    new_cache = {}

    if kind == "ssm":
        h, c_new, state = ssm_block(
            p["ssm"],
            norm(p["norm"], x, cfg),
            cfg,
            cache=cache.get("ssm") if cache else None,
        )
        x = x + h
        if mode == "prefill":
            collected = {"ssm": state}
        if cache is not None:
            new_cache["ssm"] = c_new
        return x, new_cache or None, collected, aux

    if kind == "rec":
        h, c_new, state = recurrent_block(
            p["rec"],
            norm(p["norm"], x, cfg),
            cfg,
            cache=cache.get("rec") if cache else None,
        )
        x = x + h
        if mode == "prefill":
            collected = {"rec": state}
        if cache is not None:
            new_cache["rec"] = c_new
        h2 = mlp(p["mlp"], norm(p["mlp_norm"], x, cfg), cfg.mlp_kind)
        x = x + h2
        return x, new_cache or None, collected, aux

    if kind == "xattn":
        pos = positions
        slot = None
        if decode_pos is not None and cache is not None:
            slot = decode_pos % cache["self"]["k"].shape[1]
        h, c_self, kv = gqa_attention(
            p["self_attn"],
            norm(p["norm1"], x, cfg),
            cfg,
            pos,
            causal=True,
            cache=None if cache is None else cache["self"],
            cache_slot=slot,
            use_rope=cfg.rope in ("rope", "mrope"),
        )
        x = x + h
        h, _, _ = gqa_attention(
            p["cross_attn"],
            norm(p["norm2"], x, cfg),
            cfg,
            pos,
            causal=False,
            kv_from=enc_out,
            is_cross=True,
            cache=None if cache is None else cache["cross"],
            use_rope=False,
        )
        x = x + h
        h = mlp(p["mlp"], norm(p["norm3"], x, cfg), cfg.mlp_kind)
        x = x + h
        if mode == "prefill":
            collected = {"self_kv": kv}
        elif mode == "decode":
            collected = {"delta": kv}
        return x, new_cache or None, collected, aux

    # kind == "attn"
    window = cfg.attn_window
    sub_cache = cache.get("attn") if cache else None
    slot = None
    if cfg.attn_kind == "mla":
        h, c_new, kv = mla_attention(
            p["attn"],
            norm(p["norm"], x, cfg),
            cfg,
            positions,
            cache=sub_cache,
            cache_slot=slot,
        )
    else:
        h, c_new, kv = gqa_attention(
            p["attn"],
            norm(p["norm"], x, cfg),
            cfg,
            positions,
            causal=True,
            window=window,
            cache=sub_cache,
            cache_slot=slot,
            mrope_positions=mrope_positions,
        )
    x = x + h
    x = constrain(x, "batch", "seq", "embed")

    if is_moe_layer:
        h2, aux = moe_ffn(p["moe"], norm(p["mlp_norm"], x, cfg), cfg)
    else:
        h2 = mlp(p["mlp"], norm(p["mlp_norm"], x, cfg), cfg.mlp_kind)
    x = x + h2
    x = constrain(x, "batch", "seq", "embed")

    if mode == "prefill":
        collected = {"kv": kv}
    elif mode == "decode" and sub_cache is not None:
        collected = {"delta": kv}
    return x, new_cache or None, collected, aux


def apply_unit(
    pat: tuple,
    unit_params: dict,
    x,
    cfg,
    positions,
    *,
    mode: str,
    cache=None,
    enc_out=None,
    mrope_positions=None,
    moe_flags: tuple = (),
    decode_pos=None,
):
    new_cache, collected = {}, {}
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(pat):
        key = f"b{i}"
        x, nc, col, a = apply_subblock(
            kind,
            unit_params[key],
            x,
            cfg,
            positions,
            mode=mode,
            cache=None if cache is None else cache[key],
            enc_out=enc_out,
            mrope_positions=mrope_positions,
            is_moe_layer=bool(moe_flags[i]) if moe_flags else cfg.is_moe,
            decode_pos=decode_pos,
        )
        if nc is not None:
            new_cache[key] = nc
        if col is not None:
            collected[key] = col
        aux = aux + a
    return x, (new_cache or None), (collected or None), aux


def scan_units(
    pat,
    stacked_params,
    x,
    cfg,
    positions,
    *,
    mode: str,
    cache=None,
    enc_out=None,
    mrope_positions=None,
    moe_flags=(),
    remat: bool = True,
    decode_pos=None,
):
    """lax.scan over stacked units. Returns (x, caches, collected, aux)."""

    def body(carry, xs):
        x = carry
        lp, cache_l = xs
        x, nc, col, aux = apply_unit(
            pat,
            lp,
            x,
            cfg,
            positions,
            mode=mode,
            cache=cache_l,
            enc_out=enc_out,
            mrope_positions=mrope_positions,
            moe_flags=moe_flags,
            decode_pos=decode_pos,
        )
        return x, (nc, col, aux)

    fn = (
        jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        if remat
        else body
    )
    # (measured: unrolling the decode loop is WORSE — every per-layer
    # cache slice stays live at once, +8 GiB on deepseek decode_32k;
    # the rolled loop reuses one slice buffer. Recorded in §Perf It.H.)
    x, (caches, collected, aux) = jax.lax.scan(fn, x, (stacked_params, cache))
    return x, caches, collected, jnp.sum(aux)
