"""Shared model building blocks (pure functions over param dicts)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constrain


def rms_norm(x, scale, eps: float = 1e-6, offset: float = 0.0):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (offset + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta: float = 1_000_000.0):
    """Multimodal RoPE (Qwen2-VL): rotary dims split into (t, h, w)
    sections, each rotated by its own position stream.

    x: (B, S, H, D); positions3: (3, B, S) — equal streams for text.
    sections: per-section half-dim counts, sum == D/2.
    """
    D = x.shape[-1]
    half = D // 2
    assert sum(sections) == half, (sections, D)
    freqs = rope_frequencies(D, theta)  # (half,)
    # build the per-dim position by section
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=half
    )  # (half,) static
    pos = positions3[sec_id]  # (half, B, S)
    ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs  # (B, S, half)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def _act(kind: str, x):
    if kind == "swiglu":
        return jax.nn.silu(x)
    if kind == "geglu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.gelu(x, approximate=True)


def mlp(p, x, kind: str):
    """Gated (swiglu/geglu) or plain (gelu) MLP. x: (B, S, d)."""
    if kind in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        h = jnp.einsum("bsd,df->bsf", x, p["wi"])
        h = _act(kind, g) * h
    else:
        h = _act(kind, jnp.einsum("bsd,df->bsf", x, p["wi"]))
    h = constrain(h, "batch", "seq", "ffn")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


def embed_tokens(embedding, tokens, scale: bool, d_model: int):
    x = embedding[tokens]
    if scale:
        x = x * jnp.asarray(d_model**0.5, x.dtype)
    return x


def unembed(p, x, tie_embeddings: bool):
    if tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, p["tok_embed"])
    return jnp.einsum("bsd,dv->bsv", x, p["lm_head"])


def conv1d_causal(x, w, b=None, cache=None):
    """Depthwise causal 1D conv. x: (B, S, C); w: (K, C).

    With ``cache`` (B, K-1, C): single-step decode returning new cache.
    """
    K = w.shape[0]
    if cache is not None:
        # x is (B, 1, C)
        window = jnp.concatenate([cache, x], axis=1)  # (B, K, C)
        y = jnp.einsum("bkc,kc->bc", window, w)[:, None, :]
        if b is not None:
            y = y + b
        return y, window[:, 1:, :]
    pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    # windows: (B, S, K, C) via K static slices (cheap, avoids gather)
    S = x.shape[1]
    y = sum(
        xp[:, i : i + S, :] * w[i][None, None, :] for i in range(K)
    )
    if b is not None:
        y = y + b
    return y, xp[:, -(K - 1) :, :] if K > 1 else None
