"""Fault-tolerant training supervision.

On a real multi-pod deployment the failure plane is: chips die, hosts
drop heartbeats, steps straggle.  This module implements the control
logic — heartbeat tracking, straggler deadlines, restart-with-rescale —
against an abstract ClusterMonitor, plus a simulator backend so the
policies are testable on one CPU.  The integration points with the
training loop are:

  * every step runs under a deadline; a straggling step marks the
    offending hosts suspect (on TPU: the step itself is synchronous, so
    the *next* heartbeat round localizes the slow host),
  * a failed heartbeat triggers restore-from-checkpoint; if spare hosts
    are unavailable the supervisor re-meshes to fewer data-parallel
    replicas (elastic restore path in checkpoint.py — global arrays are
    re-sharded onto the surviving mesh),
  * all decisions are logged as structured events for the fleet layer.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from enum import Enum
from typing import Callable, Optional


class HostState(Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclass
class FTConfig:
    heartbeat_interval_s: float = 10.0
    heartbeat_timeout_s: float = 30.0
    step_deadline_s: float = 120.0
    suspect_strikes: int = 2  # suspects after N missed deadlines
    min_data_parallel: int = 2  # refuse to shrink below this


@dataclass
class ClusterEvent:
    t: float
    kind: str
    detail: dict


class ClusterMonitor:
    """Tracks host health from heartbeats + step timing."""

    def __init__(
        self,
        hosts: list[str],
        cfg: FTConfig,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.cfg = cfg
        self.clock = clock
        self.state = {h: HostState.HEALTHY for h in hosts}
        self.last_beat = {h: clock() for h in hosts}
        self.strikes = {h: 0 for h in hosts}
        self.events: list[ClusterEvent] = []

    def _log(self, kind: str, **detail):
        self.events.append(ClusterEvent(self.clock(), kind, detail))

    def heartbeat(self, host: str) -> None:
        self.last_beat[host] = self.clock()
        if self.state[host] is HostState.SUSPECT:
            self.state[host] = HostState.HEALTHY
            self.strikes[host] = 0
            self._log("host_recovered", host=host)

    def step_completed(self, duration_s: float, slow_hosts: Optional[list[str]] = None):
        if duration_s <= self.cfg.step_deadline_s:
            return
        self._log("step_straggled", duration=duration_s, hosts=slow_hosts or [])
        for h in slow_hosts or []:
            self.strikes[h] += 1
            if self.strikes[h] >= self.cfg.suspect_strikes:
                self.state[h] = HostState.SUSPECT
                self._log("host_suspect", host=h)

    def sweep(self) -> list[str]:
        """Mark hosts that missed the heartbeat timeout dead; return them."""
        now = self.clock()
        died = []
        for h, t in self.last_beat.items():
            if (
                self.state[h] is not HostState.DEAD
                and now - t > self.cfg.heartbeat_timeout_s
            ):
                self.state[h] = HostState.DEAD
                died.append(h)
                self._log("host_dead", host=h)
        return died

    def healthy_hosts(self) -> list[str]:
        return [h for h, s in self.state.items() if s is not HostState.DEAD]


@dataclass
class RescalePlan:
    data_parallel: int
    dropped_hosts: list[str]
    action: str  # "continue" | "restore_rescale" | "halt"


def plan_rescale(monitor: ClusterMonitor, current_dp: int, hosts_per_replica: int,
                 cfg: FTConfig) -> RescalePlan:
    """Decide the post-failure topology.

    Replicas are groups of hosts along the data axis; losing any host in
    a replica drops the whole replica (its shards are gone), so the new
    dp = floor(healthy_hosts / hosts_per_replica), clamped by config."""
    healthy = len(monitor.healthy_hosts())
    dead = [h for h, s in monitor.state.items() if s is HostState.DEAD]
    new_dp = healthy // hosts_per_replica
    if not dead:
        return RescalePlan(current_dp, [], "continue")
    if new_dp >= current_dp:
        return RescalePlan(current_dp, dead, "restore_rescale")
    if new_dp < cfg.min_data_parallel:
        return RescalePlan(current_dp, dead, "halt")
    return RescalePlan(new_dp, dead, "restore_rescale")


class TrainSupervisor:
    """Wraps a step function with deadline timing + recovery policy.

    ``on_restore(new_dp)`` is the caller-provided path that rebuilds the
    mesh at the new data-parallel width and restores the latest
    checkpoint onto it (see launch/train.py)."""

    def __init__(self, monitor: ClusterMonitor, cfg: FTConfig, hosts_per_replica: int,
                 current_dp: int, on_restore: Callable[[int], None]):
        self.monitor = monitor
        self.cfg = cfg
        self.hosts_per_replica = hosts_per_replica
        self.dp = current_dp
        self.on_restore = on_restore
        self.restarts = 0

    def run_step(self, step_fn: Callable[[], dict]) -> Optional[dict]:
        t0 = self.monitor.clock()
        metrics = step_fn()
        self.monitor.step_completed(self.monitor.clock() - t0)
        died = self.monitor.sweep()
        if died:
            plan = plan_rescale(self.monitor, self.dp, self.hosts_per_replica, self.cfg)
            if plan.action == "halt":
                raise RuntimeError(
                    f"cluster below min_data_parallel; dead={plan.dropped_hosts}"
                )
            self.restarts += 1
            self.dp = plan.data_parallel
            self.on_restore(plan.data_parallel)
            return None  # step result discarded; caller resumes from ckpt
        return metrics
