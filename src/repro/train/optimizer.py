"""AdamW with memory-dtype control, cosine schedule, grad clipping, and
optional int8 error-feedback gradient compression.

Moments can be stored in bfloat16 (``opt_dtype="bfloat16"``), which is
what lets the 314B MoE fit the 16 GiB/chip HBM budget on the single-pod
mesh (EXPERIMENTS.md §Dry-run); updates are always computed in f32.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    opt_dtype: str = "float32"  # moment storage dtype
    compress_grads: bool = False  # int8 + error feedback on the DP reduce


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jnp.ndarray
    ef_error: Any = None  # error-feedback residual (compression)


def init(params, ocfg: OptConfig) -> OptState:
    dt = jnp.dtype(ocfg.opt_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    mu = jax.tree.map(zeros, params)
    nu = jax.tree.map(zeros, params)
    ef = (
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
        if ocfg.compress_grads
        else None
    )
    return OptState(mu=mu, nu=nu, step=jnp.zeros((), jnp.int32), ef_error=ef)


def schedule(ocfg: OptConfig, step):
    warm = jnp.minimum(step / max(ocfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - ocfg.warmup_steps) / max(ocfg.total_steps - ocfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(math.pi * t))
    return ocfg.lr * warm * (0.1 + 0.9 * cos)


def _global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def compress_int8(g, error):
    """Symmetric per-tensor int8 quantize-dequantize with error feedback.

    Models the compressed DP all-reduce: what crosses the network is the
    int8 payload + one scale; the residual is fed back next step, so the
    bias vanishes asymptotically (EF-SGD).  Returns (decompressed, new_error).
    """
    g32 = g.astype(jnp.float32) + error.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), (g32 - deq).astype(jnp.bfloat16)


def apply(params, grads, opt: OptState, ocfg: OptConfig):
    """One AdamW step. Returns (new_params, new_opt, metrics)."""
    step = opt.step + 1

    new_ef = opt.ef_error
    if ocfg.compress_grads:
        pairs = jax.tree.map(compress_int8, grads, opt.ef_error)
        grads = jax.tree.map(
            lambda pr: pr[0], pairs, is_leaf=lambda x: isinstance(x, tuple)
        )
        new_ef = jax.tree.map(
            lambda pr: pr[1], pairs, is_leaf=lambda x: isinstance(x, tuple)
        )

    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, ocfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(ocfg, step)
    b1, b2 = ocfg.b1, ocfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu32 = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu32 = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
        mhat = mu32 / bc1
        vhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + ocfg.eps) + ocfg.weight_decay * p.astype(
            jnp.float32
        )
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    out = jax.tree.map(upd, params, grads, opt.mu, opt.nu)
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    return (
        new_params,
        OptState(mu=new_mu, nu=new_nu, step=step, ef_error=new_ef),
        {"grad_norm": gnorm, "lr": lr},
    )
