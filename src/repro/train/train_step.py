"""Distributed train step: microbatched grad accumulation + AdamW.

The step function is built once per (cfg, mesh) and jitted with
explicit in/out shardings derived from the logical-axis rules; inside,
``sharding.constrain`` annotations steer GSPMD (TP/SP/EP), and the
ZeRO-style param sharding (embed dim over the DP axes) makes the
backward pass emit reduce-scatters instead of all-reduces.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shd
from repro.models import model
from . import optimizer as optim


class TrainState(NamedTuple):
    params: dict
    opt: optim.OptState


def init_state(cfg, ocfg: optim.OptConfig, seed: int = 0) -> TrainState:
    params = model.init(cfg, seed)
    return TrainState(params=params, opt=optim.init(params, ocfg))


def abstract_state(cfg, ocfg: optim.OptConfig) -> TrainState:
    params = model.abstract(cfg)
    opt = jax.eval_shape(lambda p: optim.init(p, ocfg), params)
    return TrainState(params=params, opt=opt)


def state_pspecs(cfg, ocfg: optim.OptConfig, rules) -> TrainState:
    pspec = model.partition_pspecs(cfg, rules)
    opt = optim.OptState(
        mu=pspec,
        nu=pspec,
        step=P(),
        ef_error=pspec if ocfg.compress_grads else None,
    )
    return TrainState(params=pspec, opt=opt)


def batch_pspecs(cfg, rules, batch_tree):
    def spec(path, leaf):
        if leaf.ndim == 2:
            return rules.spec(("batch", None))
        return rules.spec(("batch", None, None))

    return jax.tree_util.tree_map_with_path(spec, batch_tree)


def make_train_step(cfg, ocfg: optim.OptConfig, *, microbatches: int = 1, remat=True):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch):
        params = state.params

        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                gacc, lacc = carry
                (loss, _), g = jax.value_and_grad(
                    lambda p: model.loss_fn(p, cfg, mb, remat=remat), has_aux=True
                )(params)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / microbatches, gacc, g
                )
                return (gacc, lacc + loss / microbatches), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_fn, (g0, jnp.zeros(())), mbs)
            grads = jax.tree.map(
                lambda g, p: g.astype(p.dtype), grads, params
            )
        else:
            (loss, _), grads = jax.value_and_grad(
                lambda p: model.loss_fn(p, cfg, batch, remat=remat), has_aux=True
            )(params)

        new_params, new_opt, om = optim.apply(params, grads, state.opt, ocfg)
        metrics = {"loss": loss, **om}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def jit_train_step(cfg, ocfg, mesh, *, microbatches=1, remat=True, seq_shard=True,
                   donate=True):
    """jit with explicit in/out shardings for the production mesh."""
    rules = shd.ShardingRules.for_config(mesh, cfg, seq_shard=seq_shard)
    sspec = state_pspecs(cfg, ocfg, rules)
    state_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), sspec,
        is_leaf=lambda x: isinstance(x, P),
    )
    step = make_train_step(cfg, ocfg, microbatches=microbatches, remat=remat)

    def wrapped(state, batch):
        with shd.use_rules(rules):
            return step(state, batch)

    batch_spec = {
        "tokens": rules.spec(("batch", None)),
        "targets": rules.spec(("batch", None)),
    }
    if cfg.is_encoder_decoder:
        batch_spec["frames"] = rules.spec(("batch", None, None))
    batch_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), batch_spec,
        is_leaf=lambda x: isinstance(x, P),
    )
    return jax.jit(
        wrapped,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,) if donate else (),
    ), rules
