"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json        tree structure, shapes, dtypes, digests
            shard_<i>.npz        one file per host-shard group
            pipeline.npz         data-pipeline + dedup-filter state
         <dir>/LATEST            atomic pointer (written last)

Properties targeted at multi-thousand-node operation:
* atomicity — shards write to a temp dir, fsync'd, then a single
  rename publishes the step; LATEST updates only after the rename, so a
  crash mid-write can never corrupt the restore point.
* async — `save(..., background=True)` snapshots device arrays to host
  then writes on a worker thread; training continues.
* elastic restore — arrays are saved unsharded-logical (per-host shard
  of the global array + metadata); `restore` re-shards onto whatever
  mesh/rules the new job brings up, so recovering with a different
  topology (e.g. after losing a pod) works.
* retention — keep_last_k garbage collection.
* integrity — content digests in the manifest, verified on restore.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _digest(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep_last_k: int = 3):
        self.dir = directory
        self.keep = keep_last_k
        os.makedirs(directory, exist_ok=True)
        self._worker: Optional[threading.Thread] = None

    # -- save ----------------------------------------------------------------

    def save(self, step: int, state, extra: Optional[dict] = None, *,
             background: bool = False) -> None:
        # snapshot to host memory synchronously (cheap vs device compute)
        leaves, treedef = _flatten(state)
        host = [np.asarray(x) for x in leaves]
        extra_host = None
        if extra is not None:
            extra_host = {k: np.asarray(v) for k, v in extra.items()}

        if background:
            self.wait()  # one outstanding save at a time
            self._worker = threading.Thread(
                target=self._write, args=(step, host, treedef, extra_host)
            )
            self._worker.start()
        else:
            self._write(step, host, treedef, extra_host)

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _write(self, step, host, treedef, extra_host) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            manifest = {
                "step": step,
                "treedef": str(treedef),
                "n_leaves": len(host),
                "leaves": [
                    {
                        "shape": list(a.shape),
                        "dtype": str(a.dtype),
                        "digest": _digest(a),
                    }
                    for a in host
                ],
            }
            np.savez(os.path.join(tmp, "shard_0.npz"),
                     **{f"leaf_{i}": a for i, a in enumerate(host)})
            if extra_host is not None:
                np.savez(os.path.join(tmp, "pipeline.npz"), **extra_host)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            with open(os.path.join(self.dir, ".LATEST.tmp"), "w") as f:
                f.write(os.path.basename(final))
                f.flush()
                os.fsync(f.fileno())
            os.rename(
                os.path.join(self.dir, ".LATEST.tmp"),
                os.path.join(self.dir, "LATEST"),
            )
            self._gc()
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    def _gc(self) -> None:
        steps = sorted(
            d for d in os.listdir(self.dir) if d.startswith("step_")
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip().split("_")[1])

    def restore(self, step: int, like, *, shardings=None, verify: bool = True):
        """Restore into the structure of ``like`` (abstract or concrete).

        ``shardings``: optional matching tree of NamedShardings — arrays
        are placed directly onto the (possibly different) mesh, which is
        what makes restart-on-a-smaller-cluster work."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "shard_0.npz"))
        leaves_meta = manifest["leaves"]
        like_leaves, treedef = _flatten(like)
        if len(like_leaves) != manifest["n_leaves"]:
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, "
                f"target structure has {len(like_leaves)}"
            )
        shard_leaves = (
            _flatten(shardings)[0]
            if shardings is not None
            else [None] * len(like_leaves)
        )
        out = []
        for i, (meta, tgt, sh) in enumerate(
            zip(leaves_meta, like_leaves, shard_leaves)
        ):
            arr = data[f"leaf_{i}"]
            if verify and _digest(arr) != meta["digest"]:
                raise IOError(f"digest mismatch on leaf {i} — corrupt checkpoint")
            if list(arr.shape) != list(tgt.shape):
                raise ValueError(
                    f"leaf {i}: checkpoint shape {arr.shape} != target {tgt.shape}"
                )
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_extra(self, step: int) -> Optional[dict]:
        p = os.path.join(self.dir, f"step_{step:08d}", "pipeline.npz")
        if not os.path.exists(p):
            return None
        data = np.load(p, allow_pickle=True)
        return {k: data[k] for k in data.files}
