"""Filter registry: one functional protocol, many AMQ implementations.

Every implementation registers a :class:`FilterImpl` record binding its
static config class (a hashable NamedTuple — jit-static) to the
protocol's operations.  The façade functions in ``repro.filters``
dispatch on ``type(cfg)``, so call sites hold an opaque ``(cfg, state)``
pair and never name a concrete filter class.

Protocol (all ops pure; states are pytrees; every op is jittable):

    make(**spec)                  -> (cfg, state)
    insert(cfg, state, keys, k)   -> state
    contains(cfg, state, keys)    -> bool[B]
    delete(cfg, state, keys, k)   -> state          (optional)
    merge(cfg, state_a, state_b)  -> state          (optional)
    probe(cfg, state, keys)       -> (state, bool[B])  # contains + I/O accounting
    stats(cfg, state)             -> dict[str, scalar]
    needs_resize(cfg, state)      -> bool[]         (optional, jittable)
    grow(cfg, state)              -> (cfg, state)   (optional, host-level)
    resize(cfg, state, **kw)      -> (cfg, state)   (optional, host-level)
    needs_shrink(cfg, state)      -> bool[]         (optional, jittable)
    shrink(cfg, state)            -> (cfg, state)   (optional, host-level)

``k`` is an optional valid-prefix count so fixed-shape (padded) batches
can carry a dynamic number of real keys through ``lax.scan``.

Resize changes array shapes, so it cannot live under ``jit`` — the
protocol splits it into a jit-friendly device predicate
(``needs_resize``) and host-level structural steps: ``grow`` is the
canonical one-step doubling (guaranteed to clear ``needs_resize``
eventually), ``resize`` takes per-family keyword targets (``new_q`` for
the QF families, ``levels``/``fanout`` for the cascade, ``factor`` for
the Bloom family).  ``needs_shrink``/``shrink`` are the mirror image:
a low-watermark device predicate plus the host-level halving step (qf
re-merges a fingerprint bit, buffered re-streams the disk QF one bit
narrower, cascade pops empty levels, sharded redistributes into half
the shards, bloom folds its cell tiling).  The façade's ``auto_grow``
and ``auto_scale`` drivers compose them into ingest loops.

Implementations registered with ``public=False`` dispatch through the
façade by config type but do not appear in ``names()`` — used for
transient wrapper structures (e.g. the in-flight incremental-resize
migration) that callers never construct by name.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional


class UnsupportedOpError(NotImplementedError):
    """A filter family (or this particular config of it) rejects an op.

    Structured — carries ``family``/``op``/``hint`` — so callers and
    drivers (``auto_grow``, ``auto_scale``, pipelines) can branch on
    capability rather than string-match a message or, worse, catch an
    ``AttributeError`` escaping from a half-bound registry record.
    Subclasses ``NotImplementedError`` so existing capability checks
    keep working.
    """

    def __init__(self, family: str, op: str, hint: str = ""):
        self.family = family
        self.op = op
        self.hint = hint
        msg = f"filter family {family!r} does not support {op!r}"
        if hint:
            msg = f"{msg} ({hint})"
        super().__init__(msg)


class FilterImpl(NamedTuple):
    name: str
    paper_section: str
    cfg_cls: type
    make: Callable  # (**spec) -> (cfg, state)
    insert: Optional[Callable]  # (cfg, state, keys, k=None) -> state; None = frozen
    contains: Callable  # (cfg, state, keys) -> bool[B]
    stats: Callable  # (cfg, state) -> dict
    delete: Optional[Callable] = None
    merge: Optional[Callable] = None
    probe: Optional[Callable] = None  # (cfg, state, keys) -> (state, bool[B])
    needs_resize: Optional[Callable] = None  # (cfg, state) -> bool[] (device)
    grow: Optional[Callable] = None  # (cfg, state) -> (cfg, state)
    resize: Optional[Callable] = None  # (cfg, state, **kw) -> (cfg, state)
    needs_shrink: Optional[Callable] = None  # (cfg, state) -> bool[] (device)
    shrink: Optional[Callable] = None  # (cfg, state) -> (cfg, state)
    # config-dependent capability (e.g. bloom deletes only when counting);
    # None means "delete works for every cfg of this type"
    can_delete: Optional[Callable] = None  # (cfg) -> bool
    # hint strings surfaced in UnsupportedOpError, keyed by op name
    op_hints: dict = {}

    def deletable(self, cfg=None) -> bool:
        if self.delete is None:
            return False
        if cfg is None or self.can_delete is None:
            return True
        return bool(self.can_delete(cfg))

    @property
    def supports_merge(self) -> bool:
        return self.merge is not None

    def require(self, op: str, cfg=None) -> Callable:
        """The bound op, or a structured :class:`UnsupportedOpError`.

        The façade's single dispatch point for optional ops: family-level
        absence (unbound op) and config-level refusal (``can_delete``)
        both surface as the same typed error.
        """
        fn = getattr(self, op, None)
        if fn is None or (op == "delete" and not self.deletable(cfg)):
            raise UnsupportedOpError(self.name, op, self.op_hints.get(op, ""))
        return fn


_BY_NAME: dict[str, FilterImpl] = {}
_BY_CFG: dict[type, FilterImpl] = {}
_INTERNAL: set[str] = set()


def register(impl: FilterImpl, public: bool = True) -> FilterImpl:
    if impl.name in _BY_NAME:
        raise ValueError(f"filter {impl.name!r} already registered")
    _BY_NAME[impl.name] = impl
    _BY_CFG[impl.cfg_cls] = impl
    if not public:
        _INTERNAL.add(impl.name)
    return impl


def names() -> tuple[str, ...]:
    return tuple(sorted(set(_BY_NAME) - _INTERNAL))


def by_name(name: str) -> FilterImpl:
    try:
        return _BY_NAME[name]
    except KeyError:
        raise KeyError(
            f"unknown filter {name!r}; registered: {', '.join(names())}"
        ) from None


def by_cfg(cfg) -> FilterImpl:
    try:
        return _BY_CFG[type(cfg)]
    except KeyError:
        raise TypeError(
            f"{type(cfg).__name__} is not a registered filter config"
        ) from None
