"""Quotient filter under the functional protocol (paper §3).

Thin functional adapter over :mod:`repro.core.quotient_filter` with a
``backend`` spec field: ``"reference"`` uses the pure-jnp bulk ops,
``"pallas"`` routes the bandwidth-bound build/probe passes through the
mode-dispatched kernel layer in :mod:`repro.kernels.ops` (Mosaic on
real TPUs, a bit-exact kernel-equivalent XLA lowering on CPU/GPU — see
``kernels.dispatch``).  Deletes always use the reference build — they
are off the hot path and the kernel wrapper only accelerates
build/probe.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quotient_filter as qf
from repro.kernels import ops as kops

from .registry import FilterImpl, register

BACKENDS = ("reference", "pallas")


class QFilterConfig(NamedTuple):
    q: int
    r: int
    slack: int = 1024
    seed: int = 0
    max_load: float = 0.75
    backend: str = "reference"
    window: int = 256  # reference lookup window (see qf.lookup)
    # low watermark: shrink only once the count fits the HALVED table at
    # this fraction of its design capacity (hysteresis vs needs_resize)
    shrink_load: float = 0.4

    @property
    def core(self) -> qf.QFConfig:
        return qf.QFConfig(
            q=self.q,
            r=self.r,
            slack=self.slack,
            seed=self.seed,
            max_load=self.max_load,
        )


def _check_backend(cfg) -> None:
    if cfg.backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {cfg.backend!r}")
    # widest remainder across levels: flat QF carries r, the layered
    # configs (buffered/cascade) derive it from p and the smallest q
    max_r = cfg.r if hasattr(cfg, "r") else cfg.p - cfg.ram_q
    if cfg.backend == "pallas" and max_r > 31:
        raise ValueError("pallas backend packs remainders in int32 lanes (r <= 31)")


def valid_mask(keys, k) -> jnp.ndarray:
    """bool[B] marking the first ``k`` rows valid (all rows if k is None)."""
    if k is None:
        return jnp.ones(keys.shape[0], jnp.bool_)
    return jnp.arange(keys.shape[0]) < jnp.asarray(k, jnp.int32)


def insert_fingerprints(
    core: qf.QFConfig, backend: str, state: qf.QFState, fq, fr, valid
) -> qf.QFState:
    """Merge a validity-masked fingerprint batch into ``state``."""
    fq, fr = qf._pad_sort(fq, fr, valid)
    k = jnp.sum(valid, dtype=jnp.int32)
    if backend == "pallas":
        return qf.merge_sorted_with(core, state, fq, fr, k, kops.build_sorted)
    return qf.insert_sorted(core, state, fq, fr, k)


def insert_keys(
    core: qf.QFConfig, backend: str, state: qf.QFState, keys, k=None
) -> qf.QFState:
    fq, fr = qf.fingerprints(core, keys)
    return insert_fingerprints(core, backend, state, fq, fr, valid_mask(keys, k))


def contains_keys(core: qf.QFConfig, backend: str, state, keys, window=256):
    if backend == "pallas":
        return kops.contains(core, state, keys)
    return qf.contains(core, state, keys, window)


def delete_masked(core: qf.QFConfig, state: qf.QFState, fq, fr, mask) -> qf.QFState:
    """Delete one copy of each fingerprint where ``mask`` is set."""
    fq, fr = qf._pad_sort(fq, fr, mask)
    return qf.delete_sorted(core, state, fq, fr, jnp.sum(mask, dtype=jnp.int32))


def batch_occurrence_rank(fq, fr, valid) -> jnp.ndarray:
    """0-based rank of each batch row among equal valid fingerprints.

    Used by the layered deletes (buffered/cascade) to route the j-th
    duplicate of a key to the j-th structure that still holds a copy.
    Equality of (fq, fr) is equality of the full p-bit fingerprint, so
    ranks computed under any (q, r) split agree.
    """
    B = fq.shape[0]
    idx = jnp.arange(B, dtype=jnp.int32)
    sq = jnp.where(valid, fq, qf.INT32_MAX)
    sr = jnp.where(valid, fr, qf.UINT32_MAX)
    sq_s, sr_s, idx_s = jax.lax.sort((sq, sr, idx), num_keys=2)
    first = qf.lex_searchsorted(sq_s, sr_s, sq_s, sr_s, "left")
    rank_s = idx - first  # position within the run of equal fingerprints
    return jnp.zeros((B,), jnp.int32).at[idx_s].set(rank_s)


def multiplicity(core: qf.QFConfig, state: qf.QFState, fq, fr) -> jnp.ndarray:
    """How many copies of each queried fingerprint the filter holds."""
    qs, rs, _ = qf.extract(core, state)
    lo = qf.lex_searchsorted(qs, rs, fq, fr, "left")
    hi = qf.lex_searchsorted(qs, rs, fq, fr, "right")
    return (hi - lo).astype(jnp.int32)


# -- protocol bindings -------------------------------------------------------


def make(**spec):
    cfg = QFilterConfig(**spec)
    _check_backend(cfg)
    return cfg, qf.empty(cfg.core)


def insert(cfg: QFilterConfig, state, keys, k=None):
    return insert_keys(cfg.core, cfg.backend, state, keys, k)


def contains(cfg: QFilterConfig, state, keys):
    return contains_keys(cfg.core, cfg.backend, state, keys, cfg.window)


def delete(cfg: QFilterConfig, state, keys, k=None):
    core = cfg.core
    fq, fr = qf.fingerprints(core, keys)
    return delete_masked(core, state, fq, fr, valid_mask(keys, k))


def merge(cfg: QFilterConfig, sa, sb):
    core = cfg.core
    return qf.merge(core, core, core, sa, sb)


def build_fn(cfg):
    """The bulk rebuild pass for this config's backend (reference jnp
    scatter vs the Pallas ``qf_build_planes`` kernel)."""
    return kops.build_sorted if cfg.backend == "pallas" else qf.build_sorted


def needs_resize(cfg: QFilterConfig, state):
    """Device predicate: at/over the paper's max-load operating point."""
    return state.n >= jnp.int32(cfg.core.capacity)


def resize(cfg: QFilterConfig, state, new_q: int):
    """Re-split the p-bit fingerprints at ``new_q`` (paper §3 'Resizing').

    Host-level structural op: the slot planes change shape.  The
    requotient+rebuild pass is one streaming device pass, routed through
    the Pallas build kernel when ``backend="pallas"``.
    """
    new_r = cfg.q + cfg.r - new_q
    if not (1 <= new_q <= 30 and 1 <= new_r):
        raise ValueError(
            f"cannot re-split p={cfg.q + cfg.r} fingerprint bits at q={new_q}"
        )
    core_new, st = qf.resize(cfg.core, state, new_q, build=build_fn(cfg))
    del core_new  # same fields as cfg.core with the new (q, r) split
    return cfg._replace(q=new_q, r=new_r), st


def grow(cfg: QFilterConfig, state):
    """One doubling step: steal one remainder bit for the quotient."""
    return resize(cfg, state, cfg.q + 1)


def _can_halve(cfg: QFilterConfig) -> bool:
    # shrinking re-merges a remainder bit: r widens by one, which must
    # stay inside the uint32 remainder plane (31 bits under pallas)
    max_r = 31 if cfg.backend == "pallas" else 32
    return cfg.q > 1 and cfg.r + 1 <= max_r


def needs_shrink(cfg: QFilterConfig, state):
    """Device predicate: the population fits the halved table at the
    low watermark (``shrink_load`` of its capacity) — the hysteresis
    band keeping grow/shrink from thrashing."""
    if not _can_halve(cfg):
        return jnp.zeros((), jnp.bool_)
    halved = cfg.core._replace(q=cfg.q - 1, r=cfg.r + 1)
    return state.n <= jnp.int32(cfg.shrink_load * halved.capacity)


def shrink(cfg: QFilterConfig, state):
    """One halving step: re-merge a quotient bit into the remainder
    (paper §3 resizing, run downward — the fp rate *improves*)."""
    if not _can_halve(cfg):
        raise ValueError(f"cannot shrink q={cfg.q}, r={cfg.r} further")
    return resize(cfg, state, cfg.q - 1)


def stats(cfg: QFilterConfig, state):
    return {
        "n": state.n,
        "load": qf.load(cfg.core, state),
        "overflow": state.overflow,
        "size_bytes": cfg.core.size_bytes,
    }


IMPL = register(
    FilterImpl(
        name="qf",
        paper_section="§3 (quotient filter: insert/may-contain/delete/merge/resize)",
        cfg_cls=QFilterConfig,
        make=make,
        insert=insert,
        contains=contains,
        stats=stats,
        delete=delete,
        merge=merge,
        needs_resize=needs_resize,
        grow=grow,
        resize=resize,
        needs_shrink=needs_shrink,
        shrink=shrink,
    )
)
