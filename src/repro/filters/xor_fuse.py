"""``xor_fuse`` — the frozen (construct-only) binary-fuse family.

The seventh registry family, and the first *frozen* one: a binary-fuse
filter (``repro.core.fuse_filter``) is built once from its key set and
then answers ``contains``/``probe`` with exactly three table reads —
~20-30% smaller than a QF holding the same set at the same fp-rate
target, at the cost of mutability.  ``insert`` and ``delete`` are
deliberately unbound: the façade surfaces them as a structured
:class:`~repro.filters.registry.UnsupportedOpError` (the capability
error path this family exists to exercise), and updates happen by
*reconstruction* — ``merge`` two frozen filters, or ``extend`` one with
a raw key batch; both re-peel from the retained sorted fingerprint
runs, which is the family's write-path cost and the reason it backs the
*cold* tier (see ``cascade.frozen_below``) rather than the ingest path.

``backend="pallas"`` routes probes through the batched 3-gather kernel
(``repro.kernels.fuse_probe``); the reference path is the plain jnp
3-gather.  Everything observable (hits, stats, I/O counters) is
backend-invariant.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.core import cost_model
from repro.core import fuse_filter as fuse
from repro.core import quotient_filter as qf

from . import iostats
from .iostats import IOCounters
from .registry import FilterImpl, register

BACKENDS = ("reference", "pallas")


class XorFuseConfig(NamedTuple):
    """Static geometry + backend (hashable; jit-static).

    Field layout mirrors :class:`repro.core.fuse_filter.FuseConfig`
    (``core`` rebuilds it) with the façade-level backend selector
    appended, the same shape the QF families use.
    """

    p: int
    fp_bits: int
    segment_length: int
    segment_count: int
    capacity: int
    seed: int = 0
    backend: str = "reference"

    @property
    def core(self) -> fuse.FuseConfig:
        return fuse.FuseConfig(
            p=self.p,
            fp_bits=self.fp_bits,
            segment_length=self.segment_length,
            segment_count=self.segment_count,
            capacity=self.capacity,
            seed=self.seed,
        )

    @property
    def size_bytes(self) -> int:
        """Probe-structure bytes (the resident, randomly-read tier)."""
        return self.core.size_bytes

    @property
    def run_bytes(self) -> int:
        """Retained-run bytes (sequential-only; read by reconstruction)."""
        return self.core.run_bytes

    @property
    def bits_per_key(self) -> float:
        return self.core.slots * self.fp_bits / max(self.capacity, 1)


class XorFuseState(NamedTuple):
    core: fuse.FuseState
    io: IOCounters


def _cfg_from_core(core: fuse.FuseConfig, backend: str) -> XorFuseConfig:
    return XorFuseConfig(*core, backend=backend)


def make(
    capacity: Optional[int] = None,
    p: int = 26,
    keys=None,
    fp_bits: Optional[int] = None,
    seed: int = 0,
    backend: str = "reference",
    segment_length: Optional[int] = None,
    segment_count: Optional[int] = None,
):
    """Construct a frozen filter: ``make(keys=...)`` builds it outright,
    ``make(capacity=...)`` sizes an empty one for later ``merge``/
    ``extend`` unions (both may be given; capacity must then cover the
    keys).  ``segment_count`` is normally derived; accepting it keeps
    ``make(**cfg._asdict())`` round-trips (pipeline snapshots) exact."""
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    if capacity is None:
        if keys is None:
            raise ValueError("xor_fuse.make needs capacity=, keys=, or both")
        capacity = max(int(keys.shape[0]), 1)
    if segment_count is not None:
        core = fuse.FuseConfig(
            p=p,
            fp_bits=fp_bits,
            segment_length=segment_length,
            segment_count=segment_count,
            capacity=capacity,
            seed=seed,
        )
    else:
        core = fuse.make_config(
            capacity, p, fp_bits=fp_bits, seed=seed, segment_length=segment_length
        )
    st = fuse.empty(core) if keys is None else fuse.freeze_keys(core, keys)
    # construction streams the key set in and writes table + run out
    io = iostats.zeros()
    if keys is not None:
        io = io._replace(
            seq_write_bytes=jnp.float32(core.size_bytes + core.run_bytes),
            flushes=jnp.int32(1),
        )
    return _cfg_from_core(core, backend), XorFuseState(core=st, io=io)


def _lookup(cfg: XorFuseConfig, core_state: fuse.FuseState, keys):
    if cfg.backend == "pallas":
        from repro.kernels import ops as kernel_ops

        return kernel_ops.fuse_contains(cfg.core, core_state, keys)
    return fuse.contains(cfg.core, core_state, keys)


def contains(cfg: XorFuseConfig, state: XorFuseState, keys):
    return _lookup(cfg, state.core, keys)


def probe(cfg: XorFuseConfig, state: XorFuseState, keys):
    """``contains`` + the 3-read access schedule per query (the frozen
    tier's probe cost — cf. ``cost_model.FUSE_PROBE_READS``)."""
    hit = _lookup(cfg, state.core, keys)
    reads = jnp.where(
        state.core.n > 0,
        jnp.int32(cost_model.FUSE_PROBE_READS * keys.shape[0]),
        jnp.int32(0),
    )
    io = state.io._replace(rand_page_reads=state.io.rand_page_reads + reads)
    return state._replace(io=io), hit


def _refreeze(cfg: XorFuseConfig, fq, fr, n: int, io: IOCounters) -> XorFuseState:
    if n > cfg.capacity:
        raise ValueError(
            f"union of {n} fingerprints exceeds frozen capacity "
            f"{cfg.capacity}; make the filter with a larger capacity"
        )
    st = fuse.freeze(cfg.core, fq, fr, n)
    io = io._replace(
        seq_read_bytes=io.seq_read_bytes + jnp.float32(cfg.run_bytes),
        seq_write_bytes=io.seq_write_bytes
        + jnp.float32(cfg.size_bytes + cfg.run_bytes),
        merges=io.merges + 1,
    )
    return XorFuseState(core=st, io=io)


def merge(cfg: XorFuseConfig, sa: XorFuseState, sb: XorFuseState) -> XorFuseState:
    """Union two frozen filters (same cfg): merge the retained sorted
    runs in O(n) (no decode — frozen states store their streams
    directly) and re-peel.  Host-level, like every structural op."""
    mq, mr = qf.merge_streams(
        sa.core.run_q,
        sa.core.run_r,
        sa.core.n,
        sb.core.run_q,
        sb.core.run_r,
        sb.core.n,
    )
    n = int(sa.core.n) + int(sb.core.n)
    return _refreeze(cfg, mq, mr, n, iostats.add(sa.io, sb.io))


def extend(cfg: XorFuseConfig, state: XorFuseState, keys) -> XorFuseState:
    """Union a frozen filter with a raw key batch — the explicit,
    host-level write path (one full re-peel per call; batch your
    updates).  This is reconstruction, not insertion: the façade's
    ``insert`` stays an :class:`UnsupportedOpError` so hot ingest loops
    cannot silently adopt an O(n)-per-batch structure."""
    fq, fr = fuse.key_fingerprints(cfg.core, keys)
    sq, sr = qf._pad_sort(fq, fr, jnp.ones(fq.shape[0], jnp.bool_))
    mq, mr = qf.merge_streams(
        state.core.run_q, state.core.run_r, state.core.n, sq, sr, keys.shape[0]
    )
    n = int(state.core.n) + int(keys.shape[0])
    return _refreeze(cfg, mq, mr, n, state.io)


def needs_resize(cfg: XorFuseConfig, state: XorFuseState):
    return state.core.n >= jnp.int32(cfg.capacity)


SHRINK_LOAD = 0.4  # QF-family hysteresis default; fixed (no config knob —
# a frozen filter's shrink is an explicit host decision, never auto_scale's)


def needs_shrink(cfg: XorFuseConfig, state: XorFuseState):
    if cfg.capacity < 2:
        return jnp.zeros((), jnp.bool_)
    return state.core.n <= jnp.int32(int(SHRINK_LOAD * (cfg.capacity // 2)))


def shrink(cfg: XorFuseConfig, state: XorFuseState):
    """Halve the design capacity by one re-peel (fewer slots, same
    fp_bits — unlike the QF's bit re-merge, the fp rate is unchanged)."""
    return resize(cfg, state, capacity=max(cfg.capacity // 2, 1))


def resize(cfg: XorFuseConfig, state: XorFuseState, capacity: int):
    """Re-freeze at a new design capacity (host-level re-peel)."""
    new_core = fuse.make_config(
        capacity, cfg.p, fp_bits=cfg.fp_bits, seed=cfg.seed
    )
    if int(state.core.n) > capacity:
        raise ValueError("new capacity below the current population")
    st = fuse.freeze(new_core, state.core.run_q, state.core.run_r, int(state.core.n))
    io = state.io._replace(
        seq_read_bytes=state.io.seq_read_bytes + jnp.float32(cfg.run_bytes),
        seq_write_bytes=state.io.seq_write_bytes
        + jnp.float32(new_core.size_bytes + new_core.run_bytes),
        resizes=state.io.resizes + 1,
    )
    return _cfg_from_core(new_core, cfg.backend), XorFuseState(core=st, io=io)


def grow(cfg: XorFuseConfig, state: XorFuseState):
    return resize(cfg, state, capacity=cfg.capacity * 2)


def stats(cfg: XorFuseConfig, state: XorFuseState) -> dict:
    return {
        "n": state.core.n,
        "n_unique": state.core.n_unique,
        "overflow": state.core.overflow,
        "load": state.core.n / jnp.float32(cfg.capacity),
        "slots": cfg.core.slots,
        "fp_bits": cfg.fp_bits,
        "bits_per_key": cfg.bits_per_key,
        "size_bytes": cfg.size_bytes,
        "run_bytes": cfg.run_bytes,
        **state.io._asdict(),
    }


IMPL = register(
    FilterImpl(
        name="xor_fuse",
        paper_section="§4 cold levels, frozen (beyond-paper: binary fuse filter)",
        cfg_cls=XorFuseConfig,
        make=make,
        insert=None,  # frozen: the façade raises UnsupportedOpError
        contains=contains,
        stats=stats,
        delete=None,
        merge=merge,
        probe=probe,
        needs_resize=needs_resize,
        grow=grow,
        resize=resize,
        needs_shrink=needs_shrink,
        shrink=shrink,
        op_hints={
            "insert": "frozen family — build with make(keys=...), or union "
            "batches via merge()/xor_fuse.extend() (full re-peel per call)",
            "delete": "frozen family — rebuild without the evicted keys, or "
            "use a QF-backed family where deletes are hot-path",
        },
    )
)
