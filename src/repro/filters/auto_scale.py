"""``auto_scale`` — the watermark-driven ingest/serving driver.

``auto_grow`` only ever ratchets capacity up, and it does so with the
blocking one-pass ``grow``.  This driver supersedes it for long-running
consumers (``data.pipeline``, ``serve.prefix_cache``):

* **up**, incrementally where the family supports it: when the high
  watermark (``needs_resize``) trips on a flat, steady, or buffered QF,
  the driver opens an :mod:`incremental_resize` migration instead of
  re-streaming the whole table under one insert — subsequent batches
  each move one bounded chunk, and the driver collapses the migration
  (re-wrapping into the original family) when its device predicate
  reports drained.  The cascade's ``grow`` appends an empty level
  (free) so it keeps the direct settle loop; its geometry ``resize``
  migrates through ``incremental_resize.begin_restructure``.  Families
  without any incremental path (bloom/sharded) keep the blocking
  ``grow`` settle loop.
* **down**, on the low watermark: ``needs_shrink`` predicates encode
  per-family hysteresis (shrink only when the population fits the
  *shrunk* structure at a comfortable margin, ``shrink_load`` of its
  capacity), so a serving cache oscillating around a boundary never
  thrashes between grow and shrink: after a shrink the count must grow
  by ``1/shrink_load`` before the high watermark can trip, and after a
  grow it must fall below ``shrink_load/2`` of the new capacity before
  the low watermark can.

Like ``auto_grow``, each predicate evaluation is one device->host sync,
so this is the host-driven ingest cadence; fully on-device ``lax.scan``
loops keep a static size by construction.
"""

from __future__ import annotations

from . import incremental_resize
from .registry import by_cfg


def _settle_up(impl, cfg, state, max_steps: int):
    for _ in range(max_steps):
        if not bool(impl.needs_resize(cfg, state)):
            return cfg, state
        cfg, state = impl.grow(cfg, state)
    raise RuntimeError(
        f"{impl.name}: still over capacity after {max_steps} grow steps"
    )


def _settle_down(impl, cfg, state, max_steps: int):
    for _ in range(max_steps):
        if not bool(impl.needs_shrink(cfg, state)):
            return cfg, state
        cfg, state = impl.shrink(cfg, state)
    return cfg, state


def auto_scale(
    cfg,
    state,
    keys,
    k=None,
    *,
    incremental: bool = True,
    chunk: int = 1024,
    buf_q: int | None = None,
    shrink: bool = True,
    max_steps: int = 32,
):
    """Insert with watermark-driven growth AND shrinkage.

    Returns the new ``(cfg, state)`` pair; callers must carry both —
    mid-migration the pair is the opaque migrating wrapper, still
    answering ``insert``/``contains``/``stats`` through the façade.
    """
    if incremental_resize.is_migrating(cfg):
        impl = by_cfg(cfg)
        # a batch the side buffer cannot absorb would overflow INSIDE the
        # insert (the post-insert settle below comes too late): collapse
        # the migration first and take the plain-filter path instead
        kb = int(keys.shape[0] if k is None else k)
        if kb + int(state.buf.n) > cfg.buf.core.capacity:
            cfg, state = incremental_resize.finish(cfg, state)
            return auto_scale(
                cfg,
                state,
                keys,
                k,
                incremental=incremental,
                chunk=chunk,
                buf_q=buf_q,
                shrink=shrink,
                max_steps=max_steps,
            )
        state = impl.require("insert")(cfg, state, keys, k)
        if bool(incremental_resize.needs_settle(cfg, state)):
            cfg, state = incremental_resize.finish(cfg, state)
        return cfg, state

    impl = by_cfg(cfg)
    can_up = impl.needs_resize is not None and impl.grow is not None
    use_incremental = incremental and incremental_resize.grows_by_migration(cfg)

    if can_up and bool(impl.needs_resize(cfg, state)):
        if use_incremental:
            cfg, state = incremental_resize.begin_restructure(
                cfg, state, chunk=chunk, buf_q=buf_q
            )
            return auto_scale(
                cfg,
                state,
                keys,
                k,
                incremental=incremental,
                chunk=chunk,
                buf_q=buf_q,
                shrink=shrink,
                max_steps=max_steps,
            )
        cfg, state = _settle_up(impl, cfg, state, max_steps)

    state = impl.require("insert")(cfg, state, keys, k)

    if can_up and bool(impl.needs_resize(cfg, state)):
        if use_incremental:
            return incremental_resize.begin_restructure(
                cfg, state, chunk=chunk, buf_q=buf_q
            )
        cfg, state = _settle_up(impl, cfg, state, max_steps)
    elif (
        shrink
        and impl.needs_shrink is not None
        and impl.shrink is not None
        and bool(impl.needs_shrink(cfg, state))
    ):
        cfg, state = _settle_down(impl, cfg, state, max_steps)
    return cfg, state


def settle(cfg, state):
    """Collapse an in-flight migration, if any (host-level, blocking).

    Call before operations the migrating wrapper does not support
    (``delete``, ``merge``) or before serializing a long-lived filter
    at a structural boundary."""
    if incremental_resize.is_migrating(cfg):
        return incremental_resize.finish(cfg, state)
    return cfg, state
