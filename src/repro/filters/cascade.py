"""Cascade filter, functional (paper §4's insert-optimized on-flash AMQ).

COLA-style hierarchy: RAM quotient filter Q0 plus a *fixed-depth* stack
of on-"disk" QFs whose capacities grow geometrically with the fanout.
The legacy ``core.cascade_filter`` dataclass drives merges from Python
(``int(state.n)`` sync per batch, lazily allocated levels); here the
level stack is a static-depth tuple inside one pytree state, and the
merge-down decision is a ``jax.lax.switch`` over device counts:

* target = smallest level i such that |Q0| + |Q1..Qi| fits level i's
  capacity (the paper's collapse rule);
* branch i k-way-merges Q0..Qi into a fresh Qi in one streaming pass
  (``qf.multi_merge``) and empties everything above it;
* branch L (no fit / Q0 not full) is the identity.

Everything — including the modeled I/O schedule in ``IOCounters`` — is
device arithmetic, so a full ingest loop compiles into one
``jax.lax.scan`` with zero host transfers.  If Q0 fills and no level
fits (undersized ``levels``), Q0 keeps absorbing into its slack and its
``overflow`` flag eventually trips — sized like the legacy default
(``levels >= log_b(n_total / capacity(Q0))``) this never happens, and
the depth is no longer a hard ceiling: ``needs_resize`` flags the
approaching saturation on device and ``grow`` deepens the stack by one
level (a host-level structural step; the façade's ``auto_grow`` ingest
driver composes the two).

**Frozen cold tier** (``frozen_below=k``): levels at depth >= k are
demoted to binary-fuse form (``repro.core.fuse_filter``) — a level is
write-once between merge-downs, so immutability costs nothing there,
and the fuse table is ~20-30% smaller than the QF at the same fp-rate
target with a fixed 3-read probe.  A merge-down whose target is frozen
peels the merged stream into a fuse table; a later merge that
*consumes* a frozen level re-expands it from its retained sorted
fingerprint run, so merge/grow/shrink/``auto_scale`` keep composing
and membership stays exact across demote -> probe -> re-expand ->
merge.  Peeling is device-resident (``fuse.freeze_stream`` hides the
data-dependent rounds in ``while_loop`` carries), so a frozen cascade's
insert/merge-down runs under the same zero-sync ``lax.switch`` as the
all-QF stack.  Deletes are refused (``UnsupportedOpError``): a fuse
table cannot unlink a key.  ``cost_model.recommend_frozen_below`` picks
k from the geometry.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import cost_model
from repro.core import fuse_filter as fuse
from repro.core import quotient_filter as qf

from . import iostats, qf_filter
from .iostats import IOCounters
from .registry import FilterImpl, UnsupportedOpError, register


class CascadeConfig(NamedTuple):
    ram_q: int  # log2 buckets of Q0
    p: int  # fingerprint bits (q + r at every level)
    fanout: int = 2  # power of two; level i has q = ram_q + (i+1)*log2(fanout)
    levels: int = 4  # static level-stack depth
    seed: int = 0
    max_load: float = 0.75
    backend: str = "reference"
    shrink_load: float = 0.5  # low watermark vs the one-shallower stack
    frozen_below: Optional[int] = None  # demote levels >= this depth to fuse form
    fuse_bits: Optional[int] = None  # frozen cell width override (default: match QF fp)

    @property
    def lb(self) -> int:
        return int(math.log2(self.fanout))

    def is_frozen(self, i: int) -> bool:
        return self.frozen_below is not None and i >= self.frozen_below

    def fuse_cfg(self, i: int) -> fuse.FuseConfig:
        """Frozen geometry of level i: sized for the level's design
        capacity, cell width matching the QF level's fp-rate target."""
        lvl = self.level_cfg(i)
        fp_bits = self.fuse_bits or cost_model.fuse_fp_bits_for(lvl.r, self.max_load)
        return fuse.make_config(lvl.capacity, self.p, fp_bits=fp_bits, seed=self.seed)

    def level_size_bytes(self, i: int) -> int:
        """Probe-structure bytes of level i (fuse table when frozen)."""
        return (
            self.fuse_cfg(i).size_bytes
            if self.is_frozen(i)
            else self.level_cfg(i).size_bytes
        )

    @property
    def cold_run_bytes(self) -> int:
        """Sequential-only re-expansion runs of the frozen levels —
        merge-path bytes, never touched by probes."""
        return sum(
            self.fuse_cfg(i).run_bytes
            for i in range(self.levels)
            if self.is_frozen(i)
        )

    def _cfg(self, q: int) -> qf.QFConfig:
        return qf.QFConfig(
            q=q,
            r=self.p - q,
            slack=max(1024, (1 << q) // 64),
            seed=self.seed,
            max_load=self.max_load,
        )

    @property
    def q0_cfg(self) -> qf.QFConfig:
        return self._cfg(self.ram_q)

    def level_cfg(self, i: int) -> qf.QFConfig:
        return self._cfg(self.ram_q + (i + 1) * self.lb)

    @property
    def size_bytes(self) -> int:
        return self.q0_cfg.size_bytes + sum(
            self.level_size_bytes(i) for i in range(self.levels)
        )


class CascadeState(NamedTuple):
    q0: qf.QFState
    levels: tuple  # length cfg.levels, element i sized by cfg.level_cfg(i)
    io: IOCounters


def _empty_level(cfg: CascadeConfig, i: int):
    if cfg.is_frozen(i):
        return fuse.empty(cfg.fuse_cfg(i))
    return qf.empty(cfg.level_cfg(i))


def make(**spec):
    cfg = CascadeConfig(**spec)
    _check_geometry(cfg)
    qf_filter._check_backend(cfg)
    return cfg, CascadeState(
        q0=qf.empty(cfg.q0_cfg),
        levels=tuple(_empty_level(cfg, i) for i in range(cfg.levels)),
        io=iostats.zeros(),
    )


# ---------------------------------------------------------------------------
# Frozen-tier plumbing: canonical streams in, fuse/QF levels out
# ---------------------------------------------------------------------------


def _canon_cfg(cfg: CascadeConfig) -> qf.QFConfig:
    """The canonical (q, r) split all cross-level streams are carried in
    (``fuse.canonical_split``); only q/r are read — never materialized."""
    qc, rc = fuse.canonical_split(cfg.p)
    return qf.QFConfig(q=qc, r=rc, slack=0, seed=cfg.seed, max_load=cfg.max_load)


def _level_stream(cfg: CascadeConfig, state: CascadeState, i: int):
    """Level i as a sorted canonical fingerprint stream ``(fq, fr, n)``.

    QF levels decode + requotient (order-preserving); frozen levels
    stream their retained run directly — the re-expansion path.
    """
    s = state.levels[i]
    if cfg.is_frozen(i):
        return fuse.extract_run(cfg.fuse_cfg(i), s)
    c = cfg.level_cfg(i)
    fq, fr, n = qf.extract(c, s)
    fq, fr = qf._requotient(fq, fr, c, _canon_cfg(cfg))
    return fq, fr, n


def _q0_stream(cfg: CascadeConfig, state: CascadeState):
    fq, fr, n = qf.extract(cfg.q0_cfg, state.q0)
    fq, fr = qf._requotient(fq, fr, cfg.q0_cfg, _canon_cfg(cfg))
    return fq, fr, n


def _build_level(cfg: CascadeConfig, i: int, allq, allr, total: int, overflow: bool):
    """Materialize level i from a sorted canonical stream (host-level)."""
    if cfg.is_frozen(i):
        st = fuse.freeze(cfg.fuse_cfg(i), allq, allr, total)
        return st._replace(overflow=st.overflow | jnp.asarray(overflow))
    tgt = cfg.level_cfg(i)
    tq, tr = qf._requotient(allq, allr, _canon_cfg(cfg), tgt)
    built = qf_filter.build_fn(cfg)(tgt, tq, tr, jnp.asarray(total, jnp.int32))
    return built._replace(overflow=built.overflow | jnp.asarray(overflow))


def _level_read_bytes(cfg: CascadeConfig, i: int) -> float:
    """Merge-path read cost of consuming level i: QF levels stream their
    table; frozen levels stream only their run (the table is not read)."""
    return (
        cfg.fuse_cfg(i).run_bytes if cfg.is_frozen(i) else cfg.level_cfg(i).size_bytes
    )


def _level_write_bytes(cfg: CascadeConfig, i: int) -> float:
    return cfg.level_size_bytes(i) + (
        cfg.fuse_cfg(i).run_bytes if cfg.is_frozen(i) else 0
    )


def _build_level_traced(cfg: CascadeConfig, i: int, allq, allr, total):
    """Materialize level i from a sorted canonical stream, traceable
    (``total`` may be a device scalar).  Frozen targets peel on device
    (:func:`fuse.freeze_stream`); a stream that exceeds the frozen
    capacity or refuses to peel sets the level's ``overflow`` flag."""
    if cfg.is_frozen(i):
        return fuse.freeze_stream(cfg.fuse_cfg(i), allq, allr, total)
    tgt = cfg.level_cfg(i)
    tq, tr = qf._requotient(allq, allr, _canon_cfg(cfg), tgt)
    return qf_filter.build_fn(cfg)(tgt, tq, tr, jnp.asarray(total, jnp.int32))


def _collapse_into(cfg: CascadeConfig, state: CascadeState, i: int) -> CascadeState:
    """Merge Q0..Q_i into a fresh Q_i; levels above i empty (paper Fig. 5).

    Every participant streams in the canonical split and the fold is
    rank arithmetic (``merge_streams_many``, sort-free); a frozen target
    peels on device, so the whole collapse — demotions included — stays
    inside the ``lax.switch`` branch."""
    parts = [_q0_stream(cfg, state)] + [
        _level_stream(cfg, state, j) for j in range(i + 1)
    ]
    allq, allr, total = qf.merge_streams_many(parts)
    overflow = state.q0.overflow
    for j in range(i + 1):
        overflow = overflow | state.levels[j].overflow
    merged = _build_level_traced(cfg, i, allq, allr, total)
    merged = merged._replace(overflow=merged.overflow | overflow)
    # I/O: stream each participating non-empty disk level in, target out
    read = jnp.zeros((), jnp.float32)
    for j in range(i + 1):
        read = read + jnp.where(
            state.levels[j].n > 0,
            jnp.float32(_level_read_bytes(cfg, j)),
            jnp.float32(0),
        )
    io = state.io._replace(
        seq_read_bytes=state.io.seq_read_bytes + read,
        seq_write_bytes=state.io.seq_write_bytes
        + jnp.float32(_level_write_bytes(cfg, i)),
        flushes=state.io.flushes + 1,
        merges=state.io.merges + 1,
    )
    new_levels = tuple(
        _empty_level(cfg, j) if j < i else (merged if j == i else state.levels[j])
        for j in range(cfg.levels)
    )
    return CascadeState(q0=qf.empty(cfg.q0_cfg), levels=new_levels, io=io)


def _maybe_collapse(cfg: CascadeConfig, state: CascadeState, full) -> CascadeState:
    """lax.switch on the collapse target (branch cfg.levels = identity)."""
    L = cfg.levels
    ns = jnp.stack([s.n for s in state.levels])
    cum = state.q0.n + jnp.cumsum(ns)
    caps = jnp.asarray([cfg.level_cfg(i).capacity for i in range(L)], jnp.int32)
    fits = cum <= caps
    target = jnp.argmax(fits).astype(jnp.int32)  # first fitting level
    branch = jnp.where(full & jnp.any(fits), target, jnp.int32(L))

    def mk(i):
        return lambda s: _collapse_into(cfg, s, i)

    return jax.lax.switch(branch, [mk(i) for i in range(L)] + [lambda s: s], state)


@functools.partial(jax.jit, static_argnums=0)
def _insert_impl(cfg: CascadeConfig, state, keys, k) -> CascadeState:
    q0 = qf_filter.insert_keys(cfg.q0_cfg, cfg.backend, state.q0, keys, k)
    state = state._replace(q0=q0)
    full = qf.load(cfg.q0_cfg, q0) >= cfg.max_load
    return _maybe_collapse(cfg, state, full)


def insert(cfg: CascadeConfig, state, keys, k=None) -> CascadeState:
    """Insert a batch; merge-downs (frozen demotions included) happen
    inside one jitted program — the eager façade call costs one
    dispatch, not a re-trace of the ``lax.switch`` collapse branches."""
    if k is None:
        k = keys.shape[0]
    return _insert_impl(cfg, state, keys, jnp.asarray(k, jnp.int32))


def _structures(cfg, state):
    yield cfg.q0_cfg, state.q0
    for i in range(cfg.levels):
        yield cfg.level_cfg(i), state.levels[i]


def _level_contains(cfg: CascadeConfig, state, i: int, keys):
    s = state.levels[i]
    if cfg.is_frozen(i):
        fc = cfg.fuse_cfg(i)
        if cfg.backend == "pallas":
            from repro.kernels import ops as kernel_ops

            return kernel_ops.fuse_contains(fc, s, keys)
        return fuse.contains(fc, s, keys)  # carries its own n > 0 guard
    c = cfg.level_cfg(i)
    return jax.lax.cond(
        s.n > 0,
        lambda: qf_filter.contains_keys(c, cfg.backend, s, keys),
        lambda: jnp.zeros(keys.shape[0], jnp.bool_),
    )


def _fused_level_hits(cfg: CascadeConfig, state, keys):
    """Per-structure hits from ONE fused kernel pass over the stack.

    Q0 and the unfrozen levels hash and sort once and share a single
    multi-window probe grid; frozen levels fold in via their 3-gather
    pass (``ops.cascade_lookup``).  Returns ``(q0_hit, [hit per
    level])`` so ``contains`` can OR and ``probe`` can keep the paper's
    top-down read accounting without a second pass.
    """
    from repro.kernels import ops as kernel_ops

    qf_ix = [i for i in range(cfg.levels) if not cfg.is_frozen(i)]
    fz_ix = [i for i in range(cfg.levels) if cfg.is_frozen(i)]
    hits = kernel_ops.cascade_lookup(
        (cfg.q0_cfg,) + tuple(cfg.level_cfg(i) for i in qf_ix),
        (state.q0,) + tuple(state.levels[i] for i in qf_ix),
        tuple(cfg.fuse_cfg(i) for i in fz_ix),
        tuple(state.levels[i] for i in fz_ix),
        keys,
    )
    per_level = dict(zip(qf_ix + fz_ix, hits[1:]))
    return hits[0], [per_level[i] for i in range(cfg.levels)]


@functools.partial(jax.jit, static_argnums=0)
def contains(cfg: CascadeConfig, state, keys):
    """Membership across the stack in one jitted program (the per-level
    ``lax.cond`` guards would otherwise re-trace on every eager call)."""
    if cfg.backend == "pallas":
        q0_hit, lvl_hits = _fused_level_hits(cfg, state, keys)
        hit = q0_hit
        for h in lvl_hits:
            hit = hit | h
        return hit
    hit = jax.lax.cond(
        state.q0.n > 0,
        lambda: qf_filter.contains_keys(cfg.q0_cfg, cfg.backend, state.q0, keys),
        lambda: jnp.zeros(keys.shape[0], jnp.bool_),
    )
    for i in range(cfg.levels):
        hit = hit | _level_contains(cfg, state, i, keys)
    return hit


@functools.partial(jax.jit, static_argnums=0)
def probe(cfg: CascadeConfig, state, keys):
    """Lookup with the paper's schedule: per query still unresolved at a
    non-empty disk level, one random page read (QF cluster) or
    ``cost_model.FUSE_PROBE_READS`` independent gathers (frozen level),
    top-down short-circuit.  Matches ``cost_model.cascade_probe_reads``.

    The modeled I/O schedule stays top-down-sequential either way; under
    the pallas backend the *device* work is the one fused pass of
    ``_fused_level_hits`` and the schedule is re-derived from its
    per-level hits."""
    if cfg.backend == "pallas":
        hit, lvl_hits = _fused_level_hits(cfg, state, keys)
    else:
        hit = qf_filter.contains_keys(cfg.q0_cfg, cfg.backend, state.q0, keys)
    reads = jnp.zeros((), jnp.int32)
    for i in range(cfg.levels):
        s = state.levels[i]
        pending = ~hit
        nonempty = s.n > 0
        per_query = (
            cost_model.FUSE_PROBE_READS
            if cfg.is_frozen(i)
            else cost_model.QF_PROBE_READS
        )
        reads = reads + jnp.where(
            nonempty,
            per_query * jnp.sum(pending, dtype=jnp.int32),
            jnp.int32(0),
        )
        level_hit = (
            lvl_hits[i]
            if cfg.backend == "pallas"
            else _level_contains(cfg, state, i, keys)
        )
        hit = hit | (pending & level_hit)
    io = state.io._replace(rand_page_reads=state.io.rand_page_reads + reads)
    return state._replace(io=io), hit


def delete(cfg: CascadeConfig, state, keys, k=None) -> CascadeState:
    """Remove one copy per key from the topmost structure holding it.

    Duplicate-safe: the j-th batch occurrence of a key targets the j-th
    stored copy in top-down order, so a batch can delete more copies of
    a key than any single level holds.

    Disk-level deletes are charged to ``IOCounters`` under the same
    schedule as ``probe``: one random page read per key targeted at a
    non-empty level (the cluster must be fetched) and one random page
    write per copy actually removed; Q0 deletes are RAM-only and free."""
    if cfg.frozen_below is not None:
        raise UnsupportedOpError(
            "cascade",
            "delete",
            "frozen_below cascades cannot unlink keys from demoted "
            "(binary-fuse) levels; use an all-QF cascade when the cold "
            "tier must support deletes",
        )
    if k is None:
        k = keys.shape[0]
    return _delete_impl(cfg, state, keys, jnp.asarray(k, jnp.int32))


@functools.partial(jax.jit, static_argnums=0)
def _delete_impl(cfg: CascadeConfig, state, keys, k) -> CascadeState:
    valid = qf_filter.valid_mask(keys, k)
    structures = [(cfg.q0_cfg, state.q0)] + [
        (cfg.level_cfg(i), state.levels[i]) for i in range(cfg.levels)
    ]
    fq0, fr0 = qf.fingerprints(cfg.q0_cfg, keys)
    rank = qf_filter.batch_occurrence_rank(fq0, fr0, valid)
    cum = jnp.zeros(keys.shape[0], jnp.int32)
    out = []
    reads = jnp.zeros((), jnp.int32)
    writes = jnp.zeros((), jnp.int32)
    for lvl, (c, s) in enumerate(structures):
        fq, fr = qf.fingerprints(c, keys)
        cnt = qf_filter.multiplicity(c, s, fq, fr)
        todel = valid & (rank >= cum) & (rank < cum + cnt)
        new = qf_filter.delete_masked(c, s, fq, fr, todel)
        if lvl > 0:  # disk-resident level
            reads = reads + jnp.where(
                s.n > 0, jnp.sum(todel, dtype=jnp.int32), jnp.int32(0)
            )
            writes = writes + (s.n - new.n)
        out.append(new)
        cum = cum + cnt
    io = state.io._replace(
        rand_page_reads=state.io.rand_page_reads + reads,
        rand_page_writes=state.io.rand_page_writes + writes,
    )
    return CascadeState(q0=out[0], levels=tuple(out[1:]), io=io)


def merge(cfg: CascadeConfig, sa, sb) -> CascadeState:
    """Union of two cascades (same cfg) as ONE streaming pass into the
    smallest level that fits the combined count (paper Fig. 5's k-way
    merge).

    The previous component-wise merge overflowed a level whenever the
    two inputs' same-index levels were each more than half full — the
    collapse trigger only looked at Q0's load.  Choosing the target by
    the *total* count can never oversubscribe a level that fits; if even
    the bottom level cannot hold the union, the merge streams into the
    bottom anyway and the ``overflow`` flag reports the (physically
    unavoidable) oversubscription — ``grow``/``resize`` the inputs
    first.

    All 2L + 2 components stream in the canonical split and fold by
    rank arithmetic (``merge_streams_many`` — sort-free); each
    ``lax.switch`` branch re-splits elementwise and rebuilds at its
    target geometry.  Frozen targets peel on device
    (``fuse.freeze_stream``), frozen inputs re-expand from their
    retained runs, so frozen and all-QF cascades share this one
    device-resident path.
    """
    L = cfg.levels
    parts = [_q0_stream(cfg, sa), _q0_stream(cfg, sb)]
    for j in range(L):
        parts.append(_level_stream(cfg, sa, j))
        parts.append(_level_stream(cfg, sb, j))
    allq, allr, total = qf.merge_streams_many(parts)
    overflow = sa.q0.overflow | sb.q0.overflow
    for s in (sa, sb):
        for lv in s.levels:
            overflow = overflow | lv.overflow

    read = jnp.zeros((), jnp.float32)
    for j in range(L):
        for s in (sa.levels[j], sb.levels[j]):
            read = read + jnp.where(
                s.n > 0, jnp.float32(_level_read_bytes(cfg, j)), jnp.float32(0)
            )
    io = iostats.add(sa.io, sb.io)
    io = io._replace(seq_read_bytes=io.seq_read_bytes + read, merges=io.merges + 1)

    caps = jnp.asarray([cfg.level_cfg(i).capacity for i in range(L)], jnp.int32)
    fits = total <= caps
    branch = jnp.where(jnp.any(fits), jnp.argmax(fits), L - 1).astype(jnp.int32)

    def mk(i):
        def build_at(args):
            allq, allr, io = args
            merged = _build_level_traced(cfg, i, allq, allr, total)
            merged = merged._replace(overflow=merged.overflow | overflow)
            io2 = io._replace(
                seq_write_bytes=io.seq_write_bytes
                + jnp.float32(_level_write_bytes(cfg, i))
            )
            levels = tuple(
                merged if j == i else _empty_level(cfg, j) for j in range(L)
            )
            return CascadeState(q0=qf.empty(cfg.q0_cfg), levels=levels, io=io2)

        return build_at

    return jax.lax.switch(branch, [mk(i) for i in range(L)], (allq, allr, io))


def _restream_host(new_cfg: CascadeConfig, parts, io, overflow):
    """Collapse canonical streams into the smallest fitting level of
    ``new_cfg`` (host-level; the tail of the geometry-changing resize).
    ``parts`` is a list of ``(fq, fr, n)`` canonical streams."""
    L = new_cfg.levels
    total = int(jax.device_get(sum(p[2] for p in parts)))  # one batched sync
    target = next(
        (i for i in range(L) if total <= new_cfg.level_cfg(i).capacity), L - 1
    )
    if new_cfg.is_frozen(target) and total > new_cfg.fuse_cfg(target).capacity:
        raise ValueError(
            f"union of {total} keys exceeds the bottom frozen level's "
            f"capacity {new_cfg.fuse_cfg(target).capacity}; grow/resize first"
        )
    allq, allr, _ = qf.merge_streams_many(parts)
    merged = _build_level(new_cfg, target, allq, allr, total, overflow)
    io = io._replace(
        seq_write_bytes=io.seq_write_bytes
        + jnp.float32(_level_write_bytes(new_cfg, target)),
        merges=io.merges + 1,
    )
    levels = tuple(
        merged if j == target else _empty_level(new_cfg, j) for j in range(L)
    )
    return CascadeState(q0=qf.empty(new_cfg.q0_cfg), levels=levels, io=io)


def _all_streams(cfg: CascadeConfig, state: CascadeState):
    """Every component of one cascade as canonical streams, plus the
    merge-path read bytes and the or'd overflow flag (host values)."""
    ns, ovf = jax.device_get(
        (
            jnp.stack([s.n for s in state.levels]),
            jnp.stack([state.q0.overflow] + [s.overflow for s in state.levels]),
        )
    )  # one batched sync for the whole walk, not 2L+1 scalar pulls
    parts = [_q0_stream(cfg, state)]
    overflow = ovf.any()
    read = 0.0
    for j in range(cfg.levels):
        parts.append(_level_stream(cfg, state, j))
        if ns[j] > 0:
            read += _level_read_bytes(cfg, j)
    return parts, read, overflow


def needs_resize(cfg: CascadeConfig, state):
    """Device predicate: a full Q0 could fail to collapse anywhere —
    i.e. Q0's capacity plus everything already on disk no longer fits
    the bottom level (the paper's ``levels >= log_b(n/cap0)`` sizing).
    Q0's *actual* count is taken when it exceeds the design capacity
    (a large batch can overshoot into the slack), so the predicate
    cannot report False while a collapse is already impossible."""
    ns = jnp.stack([s.n for s in state.levels])
    q0_worst = jnp.maximum(state.q0.n, jnp.int32(cfg.q0_cfg.capacity))
    return q0_worst + jnp.sum(ns) > jnp.int32(cfg.level_cfg(cfg.levels - 1).capacity)


def _check_geometry(cfg: CascadeConfig) -> None:
    if cfg.fanout < 2 or (cfg.fanout & (cfg.fanout - 1)):
        raise ValueError("fanout must be a power of two >= 2")
    if cfg.levels < 1:
        raise ValueError("need at least one disk level")
    if cfg.ram_q + (cfg.levels) * cfg.lb >= cfg.p:
        raise ValueError("fingerprint bits p too small for the deepest level")
    if cfg.frozen_below is not None:
        if cfg.frozen_below < 0:
            raise ValueError("frozen_below must be a depth >= 0")
        fuse.canonical_split(cfg.p)  # frozen levels carry canonical streams
        for i in range(cfg.frozen_below, cfg.levels):
            cfg.fuse_cfg(i)  # validates the per-level fuse geometry


def grow(cfg: CascadeConfig, state):
    """Deepen the level stack by one (host-level structural op).

    The new bottom level starts empty, so no data moves — growth cost
    is deferred to the collapse that eventually fills it (charged there
    as usual).  Requires fingerprint headroom: the new deepest level
    still needs r >= 1 remainder bits.
    """
    new_cfg = cfg._replace(levels=cfg.levels + 1)
    _check_geometry(new_cfg)
    return new_cfg, CascadeState(
        q0=state.q0,
        levels=state.levels + (_empty_level(new_cfg, cfg.levels),),
        io=state.io._replace(resizes=state.io.resizes + 1),
    )


def needs_shrink(cfg: CascadeConfig, state):
    """Device predicate: the deepest level is empty AND the rest of the
    hierarchy (with Q0 at its worst-case design fill, mirroring
    ``needs_resize``) fits the one-shallower stack at the low
    watermark — popping the level then cannot immediately re-trip
    ``needs_resize``."""
    if cfg.levels <= 1:
        return jnp.zeros((), jnp.bool_)
    ns = jnp.stack([s.n for s in state.levels])
    q0_worst = jnp.maximum(state.q0.n, jnp.int32(cfg.q0_cfg.capacity))
    total = q0_worst + jnp.sum(ns)
    fits = total <= jnp.int32(
        cfg.shrink_load * cfg.level_cfg(cfg.levels - 2).capacity
    )
    return (state.levels[-1].n == 0) & fits


def shrink(cfg: CascadeConfig, state):
    """Pop the (empty) deepest level — the inverse of ``grow``, and
    like it free: no data moves, only the static stack depth changes."""
    if cfg.levels <= 1:
        raise ValueError("cannot shrink a single-level cascade")
    if int(state.levels[-1].n) != 0:
        raise ValueError("deepest level is non-empty; collapse/delete first")
    new_cfg = cfg._replace(levels=cfg.levels - 1)
    return new_cfg, CascadeState(
        q0=state.q0,
        levels=state.levels[:-1],
        io=state.io._replace(resizes=state.io.resizes + 1),
    )


def resize(cfg: CascadeConfig, state, levels: int = None, fanout: int = None):
    """Re-shape the hierarchy: deepen the stack and/or widen the fanout.

    Deepening with the fanout unchanged appends empty levels (free).
    Any other geometry change re-streams the whole cascade once into
    the smallest new level that fits the total count (one sequential
    pass, charged to ``IOCounters``).
    """
    new_cfg = cfg._replace(
        levels=cfg.levels if levels is None else levels,
        fanout=cfg.fanout if fanout is None else fanout,
    )
    _check_geometry(new_cfg)
    if new_cfg.fanout == cfg.fanout and new_cfg.levels >= cfg.levels:
        extra = tuple(
            _empty_level(new_cfg, i) for i in range(cfg.levels, new_cfg.levels)
        )
        return new_cfg, CascadeState(
            q0=state.q0,
            levels=state.levels + extra,
            io=state.io._replace(resizes=state.io.resizes + 1),
        )
    if cfg.frozen_below is not None:
        # frozen levels re-expand from their runs; one host re-stream
        parts, read, overflow = _all_streams(cfg, state)
        io = state.io._replace(
            seq_read_bytes=state.io.seq_read_bytes + jnp.float32(read),
            resizes=state.io.resizes + 1,
        )
        return new_cfg, _restream_host(new_cfg, parts, io, overflow)
    # geometry change: one streaming pass into the smallest fitting level
    total = int(state.q0.n) + sum(int(s.n) for s in state.levels)
    target = next(
        (
            i
            for i in range(new_cfg.levels)
            if total <= new_cfg.level_cfg(i).capacity
        ),
        new_cfg.levels - 1,
    )
    parts = [(cfg.q0_cfg, state.q0)] + [
        (cfg.level_cfg(j), state.levels[j]) for j in range(cfg.levels)
    ]
    tgt = new_cfg.level_cfg(target)
    merged = qf.multi_merge(tgt, parts, build=qf_filter.build_fn(cfg))
    read = jnp.zeros((), jnp.float32)
    for j in range(cfg.levels):
        read = read + jnp.where(
            state.levels[j].n > 0,
            jnp.float32(cfg.level_cfg(j).size_bytes),
            jnp.float32(0),
        )
    io = state.io._replace(
        seq_read_bytes=state.io.seq_read_bytes + read,
        seq_write_bytes=state.io.seq_write_bytes + tgt.size_bytes,
        resizes=state.io.resizes + 1,
        merges=state.io.merges + 1,
    )
    new_levels = tuple(
        merged if j == target else qf.empty(new_cfg.level_cfg(j))
        for j in range(new_cfg.levels)
    )
    return new_cfg, CascadeState(
        q0=qf.empty(new_cfg.q0_cfg), levels=new_levels, io=io
    )


def stats(cfg: CascadeConfig, state):
    ns = jnp.stack([s.n for s in state.levels])
    out = {
        "n": state.q0.n + jnp.sum(ns),
        "q0_load": qf.load(cfg.q0_cfg, state.q0),
        "level_counts": ns,
        "nonempty_levels": jnp.sum((ns > 0).astype(jnp.int32)),
        "overflow": state.q0.overflow
        | jnp.any(jnp.stack([s.overflow for s in state.levels])),
        "size_bytes": cfg.size_bytes,
        **state.io._asdict(),
    }
    if cfg.frozen_below is not None:
        frozen = [i for i in range(cfg.levels) if cfg.is_frozen(i)]
        out["frozen_levels"] = len(frozen)
        out["frozen_size_bytes"] = sum(cfg.level_size_bytes(i) for i in frozen)
        out["cold_run_bytes"] = cfg.cold_run_bytes
    return out


IMPL = register(
    FilterImpl(
        name="cascade",
        paper_section="§4 (cascade filter: COLA-style QF hierarchy on flash)",
        cfg_cls=CascadeConfig,
        make=make,
        insert=insert,
        contains=contains,
        stats=stats,
        delete=delete,
        merge=merge,
        probe=probe,
        needs_resize=needs_resize,
        grow=grow,
        resize=resize,
        needs_shrink=needs_shrink,
        shrink=shrink,
        can_delete=lambda cfg: cfg.frozen_below is None,
        op_hints={
            "delete": "frozen_below cascades cannot unlink keys from "
            "demoted (binary-fuse) levels"
        },
    )
)
