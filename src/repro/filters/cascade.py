"""Cascade filter, functional (paper §4's insert-optimized on-flash AMQ).

COLA-style hierarchy: RAM quotient filter Q0 plus a *fixed-depth* stack
of on-"disk" QFs whose capacities grow geometrically with the fanout.
The legacy ``core.cascade_filter`` dataclass drives merges from Python
(``int(state.n)`` sync per batch, lazily allocated levels); here the
level stack is a static-depth tuple inside one pytree state, and the
merge-down decision is a ``jax.lax.switch`` over device counts:

* target = smallest level i such that |Q0| + |Q1..Qi| fits level i's
  capacity (the paper's collapse rule);
* branch i k-way-merges Q0..Qi into a fresh Qi in one streaming pass
  (``qf.multi_merge``) and empties everything above it;
* branch L (no fit / Q0 not full) is the identity.

Everything — including the modeled I/O schedule in ``IOCounters`` — is
device arithmetic, so a full ingest loop compiles into one
``jax.lax.scan`` with zero host transfers.  If Q0 fills and no level
fits (undersized ``levels``), Q0 keeps absorbing into its slack and its
``overflow`` flag eventually trips — sized like the legacy default
(``levels >= log_b(n_total / capacity(Q0))``) this never happens.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quotient_filter as qf

from . import iostats, qf_filter
from .iostats import IOCounters
from .registry import FilterImpl, register


class CascadeConfig(NamedTuple):
    ram_q: int  # log2 buckets of Q0
    p: int  # fingerprint bits (q + r at every level)
    fanout: int = 2  # power of two; level i has q = ram_q + (i+1)*log2(fanout)
    levels: int = 4  # static level-stack depth
    seed: int = 0
    max_load: float = 0.75
    backend: str = "reference"

    @property
    def lb(self) -> int:
        return int(math.log2(self.fanout))

    def _cfg(self, q: int) -> qf.QFConfig:
        return qf.QFConfig(
            q=q, r=self.p - q, slack=max(1024, (1 << q) // 64),
            seed=self.seed, max_load=self.max_load,
        )

    @property
    def q0_cfg(self) -> qf.QFConfig:
        return self._cfg(self.ram_q)

    def level_cfg(self, i: int) -> qf.QFConfig:
        return self._cfg(self.ram_q + (i + 1) * self.lb)

    @property
    def size_bytes(self) -> int:
        return self.q0_cfg.size_bytes + sum(
            self.level_cfg(i).size_bytes for i in range(self.levels)
        )


class CascadeState(NamedTuple):
    q0: qf.QFState
    levels: tuple  # length cfg.levels, element i sized by cfg.level_cfg(i)
    io: IOCounters


def make(**spec):
    cfg = CascadeConfig(**spec)
    if cfg.fanout < 2 or (cfg.fanout & (cfg.fanout - 1)):
        raise ValueError("fanout must be a power of two >= 2")
    if cfg.levels < 1:
        raise ValueError("need at least one disk level")
    if cfg.ram_q + (cfg.levels) * cfg.lb >= cfg.p:
        raise ValueError("fingerprint bits p too small for the deepest level")
    qf_filter._check_backend(cfg)
    return cfg, CascadeState(
        q0=qf.empty(cfg.q0_cfg),
        levels=tuple(qf.empty(cfg.level_cfg(i)) for i in range(cfg.levels)),
        io=iostats.zeros(),
    )


def _collapse_into(cfg: CascadeConfig, state: CascadeState, i: int) -> CascadeState:
    """Merge Q0..Q_i into a fresh Q_i; levels above i empty (paper Fig. 5)."""
    parts = [(cfg.q0_cfg, state.q0)] + [
        (cfg.level_cfg(j), state.levels[j]) for j in range(i + 1)
    ]
    tgt = cfg.level_cfg(i)
    merged = qf.multi_merge(tgt, parts)
    # I/O: stream each participating non-empty disk level in, target out
    read = jnp.zeros((), jnp.float32)
    for j in range(i + 1):
        read = read + jnp.where(
            state.levels[j].n > 0,
            jnp.float32(cfg.level_cfg(j).size_bytes),
            jnp.float32(0),
        )
    io = state.io._replace(
        seq_read_bytes=state.io.seq_read_bytes + read,
        seq_write_bytes=state.io.seq_write_bytes + tgt.size_bytes,
        flushes=state.io.flushes + 1,
        merges=state.io.merges + 1,
    )
    new_levels = tuple(
        qf.empty(cfg.level_cfg(j)) if j < i else (merged if j == i else state.levels[j])
        for j in range(cfg.levels)
    )
    return CascadeState(q0=qf.empty(cfg.q0_cfg), levels=new_levels, io=io)


def _maybe_collapse(cfg: CascadeConfig, state: CascadeState, full) -> CascadeState:
    """lax.switch on the collapse target (branch cfg.levels = identity)."""
    L = cfg.levels
    ns = jnp.stack([s.n for s in state.levels])
    cum = state.q0.n + jnp.cumsum(ns)
    caps = jnp.asarray([cfg.level_cfg(i).capacity for i in range(L)], jnp.int32)
    fits = cum <= caps
    target = jnp.argmax(fits).astype(jnp.int32)  # first fitting level
    branch = jnp.where(full & jnp.any(fits), target, jnp.int32(L))

    def mk(i):
        return lambda s: _collapse_into(cfg, s, i)

    return jax.lax.switch(branch, [mk(i) for i in range(L)] + [lambda s: s], state)


def insert(cfg: CascadeConfig, state, keys, k=None) -> CascadeState:
    q0 = qf_filter.insert_keys(cfg.q0_cfg, cfg.backend, state.q0, keys, k)
    state = state._replace(q0=q0)
    return _maybe_collapse(cfg, state, qf.load(cfg.q0_cfg, q0) >= cfg.max_load)


def _structures(cfg, state):
    yield cfg.q0_cfg, state.q0
    for i in range(cfg.levels):
        yield cfg.level_cfg(i), state.levels[i]


def contains(cfg: CascadeConfig, state, keys):
    hit = jnp.zeros(keys.shape[0], jnp.bool_)
    for c, s in _structures(cfg, state):
        lvl = jax.lax.cond(
            s.n > 0,
            lambda s=s, c=c: qf_filter.contains_keys(c, cfg.backend, s, keys),
            lambda: jnp.zeros(keys.shape[0], jnp.bool_),
        )
        hit = hit | lvl
    return hit


def probe(cfg: CascadeConfig, state, keys):
    """Lookup with the paper's schedule: one random page read per
    non-empty disk level for every query still unresolved at that level
    (top-down short-circuit)."""
    hit = qf_filter.contains_keys(cfg.q0_cfg, cfg.backend, state.q0, keys)
    reads = jnp.zeros((), jnp.int32)
    for i in range(cfg.levels):
        c, s = cfg.level_cfg(i), state.levels[i]
        pending = ~hit
        nonempty = s.n > 0
        reads = reads + jnp.where(
            nonempty, jnp.sum(pending, dtype=jnp.int32), jnp.int32(0)
        )
        lvl = jax.lax.cond(
            nonempty,
            lambda s=s, c=c: qf_filter.contains_keys(c, cfg.backend, s, keys),
            lambda: jnp.zeros(keys.shape[0], jnp.bool_),
        )
        hit = hit | (pending & lvl)
    io = state.io._replace(rand_page_reads=state.io.rand_page_reads + reads)
    return state._replace(io=io), hit


def delete(cfg: CascadeConfig, state, keys, k=None) -> CascadeState:
    """Remove one copy per key from the topmost structure holding it.

    Duplicate-safe: the j-th batch occurrence of a key targets the j-th
    stored copy in top-down order, so a batch can delete more copies of
    a key than any single level holds."""
    valid = qf_filter.valid_mask(keys, k)
    structures = [(cfg.q0_cfg, state.q0)] + [
        (cfg.level_cfg(i), state.levels[i]) for i in range(cfg.levels)
    ]
    fq0, fr0 = qf.fingerprints(cfg.q0_cfg, keys)
    rank = qf_filter.batch_occurrence_rank(fq0, fr0, valid)
    cum = jnp.zeros(keys.shape[0], jnp.int32)
    out = []
    for c, s in structures:
        fq, fr = qf.fingerprints(c, keys)
        cnt = qf_filter.multiplicity(c, s, fq, fr)
        todel = valid & (rank >= cum) & (rank < cum + cnt)
        out.append(qf_filter.delete_masked(c, s, fq, fr, todel))
        cum = cum + cnt
    return state._replace(q0=out[0], levels=tuple(out[1:]))


def merge(cfg: CascadeConfig, sa, sb) -> CascadeState:
    """Union of two cascades (same cfg): component-wise QF merges, then
    one collapse pass if the combined Q0 crossed its max load."""
    q0 = qf.merge(cfg.q0_cfg, cfg.q0_cfg, cfg.q0_cfg, sa.q0, sb.q0)
    levels = tuple(
        qf.merge(cfg.level_cfg(i), cfg.level_cfg(i), cfg.level_cfg(i),
                 sa.levels[i], sb.levels[i])
        for i in range(cfg.levels)
    )
    state = CascadeState(q0=q0, levels=levels, io=iostats.add(sa.io, sb.io))
    return _maybe_collapse(cfg, state, qf.load(cfg.q0_cfg, q0) >= cfg.max_load)


def stats(cfg: CascadeConfig, state):
    ns = jnp.stack([s.n for s in state.levels])
    return {
        "n": state.q0.n + jnp.sum(ns),
        "q0_load": qf.load(cfg.q0_cfg, state.q0),
        "level_counts": ns,
        "nonempty_levels": jnp.sum((ns > 0).astype(jnp.int32)),
        "overflow": state.q0.overflow
        | jnp.any(jnp.stack([s.overflow for s in state.levels])),
        "size_bytes": cfg.size_bytes,
        **state.io._asdict(),
    }


IMPL = register(
    FilterImpl(
        name="cascade",
        paper_section="§4 (cascade filter: COLA-style QF hierarchy on flash)",
        cfg_cls=CascadeConfig,
        make=make,
        insert=insert,
        contains=contains,
        stats=stats,
        delete=delete,
        merge=merge,
        probe=probe,
    )
)
