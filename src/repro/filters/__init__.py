"""``repro.filters`` — one functional AMQ API for the whole paper.

The paper's pitch is that a single family of structures covers the
RAM-to-flash spectrum with the same operations.  This package is that
pitch as an API: every filter is an opaque ``(cfg, state)`` pair where
``cfg`` is a hashable NamedTuple (jit-static) and ``state`` is a pure
pytree, and every operation is jittable with donated state — flush and
merge triggers are ``lax.cond``/``lax.switch`` on device scalars, so a
full ingest loop runs under one ``jax.jit``/``jax.lax.scan`` with zero
per-batch host syncs.

Registry name -> implementation -> paper section:

========================  =======================================================
``"qf"``                  Quotient filter (§3): the in-RAM structure; insert,
                          may-contain, delete, merge, all bulk-parallel.
``"bloom"``               Bloom filter baseline (§2); ``counting=True`` gives the
                          counting variant [3] with delete + additive merge.
``"blocked_bloom"``       Hash-localized Bloom filter (§2, buffered BF of Canim
                          et al.): all k probes in one block/page.
``"buffered_qf"``         Buffered quotient filter (§4): RAM QF buffer flushed
                          into a large flash QF by one streaming merge.
``"cascade"``             Cascade filter (§4): COLA-style geometric hierarchy of
                          QFs, insert-optimized; fixed-depth level stack.
``"sharded_qf"``          Multi-device QF (§6 future work): quotient-prefix
                          sharding + all_to_all dispatch on a device mesh.
``"steady_qf"``           Steady-state QF (§4 RAM buffer, always-on): O(buffer)
                          inserts + background settle ticks — LSM-style.
========================  =======================================================

Quickstart::

    from repro import filters

    cfg, state = filters.make("qf", q=16, r=12)
    state = filters.insert(cfg, state, keys)        # jittable, donatable
    hits  = filters.contains(cfg, state, keys)      # bool[B], no false negatives
    state = filters.delete(cfg, state, keys[:100])

    # the same four verbs for every registered structure:
    cfg, state = filters.make("cascade", ram_q=12, p=28, fanout=4, levels=4)
    step = jax.jit(lambda s, ks: (filters.insert(cfg, s, ks), None))
    state, _ = jax.lax.scan(step, state, key_batches)   # zero host syncs

    # dynamic resizing (the paper's headline QF advantage): a jittable
    # device predicate plus host-level structural growth, composed by
    # the ``auto_grow`` ingest driver — start small, never overflow:
    cfg, state = filters.make("qf", q=10, r=18)
    for batch in stream:                            # unbounded stream
        cfg, state = filters.auto_grow(cfg, state, batch)

    # ...or, for long-running consumers, ``auto_scale``: growth happens
    # *incrementally* (each batch moves one bounded chunk of quotient
    # runs into the wider table — no stop-the-world re-stream; see
    # ``filters.incremental_resize``) and a low-watermark ``shrink``
    # reclaims capacity when the population falls, with hysteresis so
    # the structure never thrashes between the two:
    for batch in stream:
        cfg, state = filters.auto_scale(cfg, state, batch)

A ``backend="pallas"`` spec field on the QF-family filters routes the
bandwidth-bound build/probe passes through the Pallas TPU kernels in
``repro.kernels`` (interpret mode on CPU).  ``probe`` is ``contains``
plus the paper's modeled I/O schedule accounted into device counters
inside the state; convert with ``repro.filters.iostats.to_iolog``.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import (  # noqa: F401 (registration side effects)
    bloom_filter,
    buffered,
    cascade,
    incremental_resize,
    iostats,
    qf_filter,
    sharded,
    steady,
    xor_fuse,
)
from .auto_scale import auto_scale, settle
from .iostats import IOCounters, to_iolog
from .registry import (
    FilterImpl,
    UnsupportedOpError,
    by_cfg,
    by_name,
    names,
    register,
)

# every op name ``supports`` answers for; "insert" is optional since the
# frozen (xor_fuse) family is construct-only
_OPS = frozenset(
    {
        "insert",
        "contains",
        "delete",
        "merge",
        "probe",
        "stats",
        "needs_resize",
        "grow",
        "resize",
        "needs_shrink",
        "shrink",
    }
)


def make(name: str, **spec):
    """Construct a filter by registry name: ``make(name, **spec) -> (cfg, state)``."""
    return by_name(name).make(**spec)


def insert(cfg, state, keys, k=None):
    """Insert a key batch; ``k`` = optional valid-prefix count for padded batches.

    Frozen (construct-only) families raise :class:`UnsupportedOpError`.
    """
    return by_cfg(cfg).require("insert")(cfg, state, keys, k)


def contains(cfg, state, keys):
    """MAY-CONTAIN for a key batch (no false negatives)."""
    return by_cfg(cfg).contains(cfg, state, keys)


def delete(cfg, state, keys, k=None):
    """Remove one copy of each key (check ``supports(cfg, "delete")``)."""
    return by_cfg(cfg).require("delete", cfg)(cfg, state, keys, k)


def merge(cfg, state_a, state_b):
    """Union two same-config filters into one state."""
    return by_cfg(cfg).require("merge")(cfg, state_a, state_b)


def probe(cfg, state, keys):
    """``contains`` + modeled I/O accounting: returns ``(state, hits)``.

    Falls back to pure ``contains`` (state unchanged) for filters whose
    state carries no I/O counters.
    """
    impl = by_cfg(cfg)
    if impl.probe is None:
        return state, impl.contains(cfg, state, keys)
    return impl.probe(cfg, state, keys)


def stats(cfg, state) -> dict:
    """Device-scalar diagnostics (count, load, overflow, I/O counters...)."""
    return by_cfg(cfg).stats(cfg, state)


def needs_resize(cfg, state):
    """Device predicate: is the filter at/over its design capacity?

    Jittable (a ``bool[]`` scalar on device) — the cheap half of the
    resize protocol, safe to evaluate every batch inside a compiled
    ingest loop.  Filters without a resize binding report a constant
    False.  The structural ``grow``/``resize`` steps themselves change
    array shapes and must run on the host (see :func:`auto_grow`).
    """
    impl = by_cfg(cfg)
    if impl.needs_resize is None:
        return jnp.zeros((), jnp.bool_)
    return impl.needs_resize(cfg, state)


def grow(cfg, state):
    """One canonical growth step: ``(cfg, state) -> (cfg, state)``.

    Doubles the structure's capacity (QF: steal one remainder bit for
    the quotient; buffered: disk QF +1 quotient bit, one re-stream;
    cascade: one deeper level; sharded: +1 bit per shard; bloom: cell
    doubling).  Host-level — array shapes change — but the data
    movement is a single streaming device pass.
    """
    return by_cfg(cfg).require("grow")(cfg, state)


def resize(cfg, state, **kw):
    """Structural resize with per-family keyword targets:
    ``resize(cfg, state, new_q=18)`` (qf / sharded_qf),
    ``resize(cfg, state, disk_q=22)`` (buffered_qf),
    ``resize(cfg, state, levels=6, fanout=4)`` (cascade),
    ``resize(cfg, state, factor=4)`` (bloom / blocked_bloom).
    Returns the new ``(cfg, state)`` pair."""
    return by_cfg(cfg).require("resize")(cfg, state, **kw)


def needs_shrink(cfg, state):
    """Device predicate: is the filter far enough under its low
    watermark that one structural halving step is safe?

    The mirror image of :func:`needs_resize` — jittable, cheap, and
    deliberately conservative: each family's predicate only fires when
    the population fits the *shrunk* structure at a comfortable margin
    (``shrink_load`` on the config), which is the hysteresis band that
    keeps ``auto_scale`` from thrashing between grow and shrink.
    Filters without a shrink binding report a constant False.
    """
    impl = by_cfg(cfg)
    if impl.needs_shrink is None:
        return jnp.zeros((), jnp.bool_)
    return impl.needs_shrink(cfg, state)


def shrink(cfg, state):
    """One canonical halving step: ``(cfg, state) -> (cfg, state)``.

    Per family: qf re-merges a quotient bit into the remainder (the fp
    rate improves), buffered_qf re-streams its disk QF one bit
    narrower, cascade pops an empty deepest level, sharded_qf
    redistributes shard pairs and halves the shard count, bloom folds
    its doubled cell tiling back together.  Host-level — shapes change.
    """
    return by_cfg(cfg).require("shrink")(cfg, state)


def auto_grow(cfg, state, keys, k=None, max_steps: int = 32):
    """Insert with automatic growth: the dynamic-resizing ingest driver.

    Checks the device predicate before and after the insert and applies
    host-level ``grow`` steps until the structure is back under its
    design load, so an unbounded stream can be ingested through a
    filter that started at any size — the paper's "a quotient filter
    can be dynamically resized" property, end-to-end.  Returns the new
    ``(cfg, state)`` pair; callers must carry both.

    Each ``needs_resize`` evaluation is one device->host sync, so this
    driver is for host-driven ingest loops (pipelines, serving); fully
    on-device ``lax.scan`` ingest keeps a static size by construction.
    Batches should stay comfortably under the structure's slack so a
    single batch cannot overshoot capacity before the post-insert check
    runs (the QF-family default slack of 1024 covers typical batches).
    """
    impl = by_cfg(cfg)
    can = impl.needs_resize is not None and impl.grow is not None

    def settle(cfg, state):
        for _ in range(max_steps):
            if not bool(impl.needs_resize(cfg, state)):
                return cfg, state
            cfg, state = impl.grow(cfg, state)
        raise RuntimeError(
            f"{impl.name}: still over capacity after {max_steps} grow steps"
        )

    if can:
        cfg, state = settle(cfg, state)
    state = impl.require("insert")(cfg, state, keys, k)
    if can:
        cfg, state = settle(cfg, state)
    return cfg, state


def supports(name_or_cfg, op: str) -> bool:
    """Does filter ``name_or_cfg`` implement optional op ``"delete"`` /
    ``"merge"`` / ``"resize"`` / ``"grow"`` / ``"needs_resize"`` /
    ``"needs_shrink"`` / ``"shrink"``?

    Passing a cfg instance gives the config-exact answer (e.g. delete on
    a plain non-counting Bloom is False); a name answers for the family.
    Unknown op names raise ``ValueError`` (they used to fall through to
    ``getattr`` and leak an ``AttributeError`` — or worse, silently
    answer False for a typo'd op).
    """
    if op not in _OPS:
        raise ValueError(
            f"unknown filter op {op!r}; known ops: {', '.join(sorted(_OPS))}"
        )
    if isinstance(name_or_cfg, str):
        return getattr(by_name(name_or_cfg), op) is not None
    impl = by_cfg(name_or_cfg)
    if op == "delete":
        return impl.deletable(name_or_cfg)
    return getattr(impl, op) is not None


__all__ = [
    "FilterImpl",
    "IOCounters",
    "UnsupportedOpError",
    "auto_grow",
    "auto_scale",
    "by_cfg",
    "by_name",
    "contains",
    "delete",
    "grow",
    "incremental_resize",
    "insert",
    "iostats",
    "make",
    "merge",
    "names",
    "needs_resize",
    "needs_shrink",
    "probe",
    "register",
    "resize",
    "settle",
    "shrink",
    "stats",
    "supports",
    "to_iolog",
]
