"""Buffered quotient filter, functional (paper §4's RAM+flash QF).

One small RAM QF absorbs inserts; when it crosses ``max_load`` the
whole RAM QF is merged into the (much larger) disk QF by one streaming
pass (paper Fig. 5).  Unlike the legacy ``core.buffered_qf`` dataclass,
the flush trigger is a ``lax.cond`` on the device-resident count — no
``float(load)`` host sync — and the I/O schedule lives in device
counters inside the state, so an entire ingest loop runs under a single
``jax.jit`` / ``lax.scan``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quotient_filter as qf

from . import iostats, qf_filter
from .iostats import IOCounters
from .registry import FilterImpl, register


class BufferedQFConfig(NamedTuple):
    ram_q: int  # log2 buckets of the RAM QF
    disk_q: int  # log2 buckets of the disk QF
    p: int  # fingerprint bits (q + r at both levels)
    slack: int = 1024
    disk_slack: int = 0  # 0 -> same as slack
    seed: int = 0
    max_load: float = 0.75
    backend: str = "reference"
    shrink_load: float = 0.4  # low watermark vs the halved disk QF

    @property
    def ram(self) -> qf.QFConfig:
        return qf.QFConfig(
            q=self.ram_q,
            r=self.p - self.ram_q,
            slack=self.slack,
            seed=self.seed,
            max_load=self.max_load,
        )

    @property
    def disk(self) -> qf.QFConfig:
        return qf.QFConfig(
            q=self.disk_q,
            r=self.p - self.disk_q,
            slack=self.disk_slack or self.slack,
            seed=self.seed,
            max_load=self.max_load,
        )


class BufferedQFState(NamedTuple):
    ram: qf.QFState
    disk: qf.QFState
    io: IOCounters


def make(**spec):
    cfg = BufferedQFConfig(**spec)
    if cfg.ram_q >= cfg.disk_q:
        raise ValueError("disk QF must be larger than the RAM QF")
    if not (cfg.ram_q < cfg.p and cfg.disk_q < cfg.p):
        raise ValueError("fingerprint bits p must exceed both quotients")
    qf_filter._check_backend(cfg)
    return cfg, BufferedQFState(
        ram=qf.empty(cfg.ram), disk=qf.empty(cfg.disk), io=iostats.zeros()
    )


def _flush(cfg: BufferedQFConfig, state: BufferedQFState) -> BufferedQFState:
    """Merge the RAM QF into the disk QF: stream old disk in, merged out."""
    disk = qf.merge(cfg.disk, cfg.disk, cfg.ram, state.disk, state.ram)
    io = state.io._replace(
        seq_read_bytes=state.io.seq_read_bytes + cfg.disk.size_bytes,
        seq_write_bytes=state.io.seq_write_bytes + cfg.disk.size_bytes,
        flushes=state.io.flushes + 1,
        merges=state.io.merges + 1,
    )
    return BufferedQFState(ram=qf.empty(cfg.ram), disk=disk, io=io)


def flush(cfg: BufferedQFConfig, state: BufferedQFState) -> BufferedQFState:
    """Unconditional flush (exposed for the legacy shim and tests)."""
    return _flush(cfg, state)


@functools.partial(jax.jit, static_argnums=0)
def _insert_impl(cfg: BufferedQFConfig, state, keys, k) -> BufferedQFState:
    ram = qf_filter.insert_keys(cfg.ram, cfg.backend, state.ram, keys, k)
    state = state._replace(ram=ram)
    return jax.lax.cond(
        qf.load(cfg.ram, ram) >= cfg.max_load,
        lambda s: _flush(cfg, s),
        lambda s: s,
        state,
    )


def insert(cfg: BufferedQFConfig, state, keys, k=None) -> BufferedQFState:
    """Insert a batch; the flush ``lax.cond`` (full RAM->disk merge on
    the taken branch) runs inside one jitted program — the eager façade
    call costs one dispatch, not a re-trace of both branches."""
    if k is None:
        k = keys.shape[0]
    return _insert_impl(cfg, state, keys, jnp.asarray(k, jnp.int32))


def contains(cfg: BufferedQFConfig, state, keys):
    ram_hit = qf_filter.contains_keys(cfg.ram, cfg.backend, state.ram, keys)
    disk_hit = qf_filter.contains_keys(cfg.disk, cfg.backend, state.disk, keys)
    return ram_hit | disk_hit


def probe(cfg: BufferedQFConfig, state, keys):
    """Lookup with the paper's I/O schedule: RAM misses each cost one
    random page read against the disk QF (cluster fits a page, §3)."""
    ram_hit = qf_filter.contains_keys(cfg.ram, cfg.backend, state.ram, keys)
    disk_hit = qf_filter.contains_keys(cfg.disk, cfg.backend, state.disk, keys)
    reads = jnp.where(
        state.disk.n > 0, jnp.sum(~ram_hit, dtype=jnp.int32), jnp.int32(0)
    )
    io = state.io._replace(rand_page_reads=state.io.rand_page_reads + reads)
    return state._replace(io=io), ram_hit | disk_hit


def delete(cfg: BufferedQFConfig, state, keys, k=None) -> BufferedQFState:
    """Remove one copy per key, RAM first, then disk.

    Duplicate-safe: the j-th batch occurrence of a key targets the j-th
    stored copy across RAM-then-disk, so deleting more copies than the
    RAM QF holds correctly spills the remainder onto the disk QF
    (fingerprints are consistent across both (q, r) splits).

    Disk-targeted deletes are charged to ``IOCounters`` under the same
    schedule as ``probe``: one random page read per targeted key (the
    cluster must be fetched to locate the copy) and one random page
    write per copy actually removed."""
    valid = qf_filter.valid_mask(keys, k)
    rq, rr = qf.fingerprints(cfg.ram, keys)
    rank = qf_filter.batch_occurrence_rank(rq, rr, valid)
    cnt_ram = qf_filter.multiplicity(cfg.ram, state.ram, rq, rr)
    ram = qf_filter.delete_masked(
        cfg.ram, state.ram, rq, rr, valid & (rank < cnt_ram)
    )
    dq, dr = qf.fingerprints(cfg.disk, keys)
    disk_mask = valid & (rank >= cnt_ram)
    disk = qf_filter.delete_masked(cfg.disk, state.disk, dq, dr, disk_mask)
    reads = jnp.where(
        state.disk.n > 0, jnp.sum(disk_mask, dtype=jnp.int32), jnp.int32(0)
    )
    io = state.io._replace(
        rand_page_reads=state.io.rand_page_reads + reads,
        rand_page_writes=state.io.rand_page_writes + (state.disk.n - disk.n),
    )
    return BufferedQFState(ram=ram, disk=disk, io=io)


def merge(cfg: BufferedQFConfig, sa, sb) -> BufferedQFState:
    """Union of two buffered QFs (same cfg): disk_a + disk_b + ram_b
    stream into the new disk; ram_a stays the active buffer."""
    disk = qf.multi_merge(
        cfg.disk,
        [(cfg.disk, sa.disk), (cfg.disk, sb.disk), (cfg.ram, sb.ram)],
    )
    io = iostats.add(sa.io, sb.io)
    io = io._replace(
        seq_read_bytes=io.seq_read_bytes + 2.0 * cfg.disk.size_bytes,
        seq_write_bytes=io.seq_write_bytes + cfg.disk.size_bytes,
        merges=io.merges + 1,
    )
    return BufferedQFState(ram=sa.ram, disk=disk, io=io)


def needs_resize(cfg: BufferedQFConfig, state):
    """Device predicate: the disk QF's (post-flush) load crossed
    ``max_load`` — the next flush would push it past the paper's
    operating point."""
    return qf.load(cfg.disk, state.disk) >= cfg.max_load


def _restream(cfg: BufferedQFConfig, new_disk: qf.QFConfig, disk_state):
    """One streaming requotient pass of the disk QF into a new geometry
    (Pallas build kernel when backend="pallas")."""
    return qf.multi_merge(
        new_disk, [(cfg.disk, disk_state)], build=qf_filter.build_fn(cfg)
    )


def resize(cfg: BufferedQFConfig, state, disk_q: int):
    """Re-split the disk QF at ``disk_q`` (host-level structural op).

    The whole disk QF is re-streamed once — sequential read of the old
    structure, sequential write of the new one — which is exactly the
    paper's merge I/O schedule, charged to ``IOCounters``.
    """
    if not (cfg.ram_q < disk_q < cfg.p):
        raise ValueError(
            f"disk_q={disk_q} must lie strictly between ram_q={cfg.ram_q} "
            f"and p={cfg.p}"
        )
    new_cfg = cfg._replace(disk_q=disk_q)
    disk = _restream(cfg, new_cfg.disk, state.disk)
    io = state.io._replace(
        seq_read_bytes=state.io.seq_read_bytes + cfg.disk.size_bytes,
        seq_write_bytes=state.io.seq_write_bytes + new_cfg.disk.size_bytes,
        resizes=state.io.resizes + 1,
    )
    return new_cfg, BufferedQFState(ram=state.ram, disk=disk, io=io)


def grow(cfg: BufferedQFConfig, state):
    """One doubling step of the disk QF (steal one remainder bit)."""
    return resize(cfg, state, cfg.disk_q + 1)


def needs_shrink(cfg: BufferedQFConfig, state):
    """Device predicate: the disk population fits the halved disk QF at
    the low watermark — one narrower re-stream reclaims half the flash."""
    if cfg.disk_q - 1 <= cfg.ram_q:
        return jnp.zeros((), jnp.bool_)
    halved = cfg.disk._replace(q=cfg.disk_q - 1, r=cfg.disk.r + 1)
    return state.disk.n <= jnp.int32(cfg.shrink_load * halved.capacity)


def shrink(cfg: BufferedQFConfig, state):
    """One halving step of the disk QF (re-merge a remainder bit)."""
    if cfg.disk_q - 1 <= cfg.ram_q:
        raise ValueError(
            f"cannot shrink disk_q={cfg.disk_q}: must stay above ram_q={cfg.ram_q}"
        )
    return resize(cfg, state, cfg.disk_q - 1)


def stats(cfg: BufferedQFConfig, state):
    return {
        "n": state.ram.n + state.disk.n,
        "ram_load": qf.load(cfg.ram, state.ram),
        "disk_load": qf.load(cfg.disk, state.disk),
        "overflow": state.ram.overflow | state.disk.overflow,
        "size_bytes": cfg.ram.size_bytes + cfg.disk.size_bytes,
        **state.io._asdict(),
    }


IMPL = register(
    FilterImpl(
        name="buffered_qf",
        paper_section="§4 (buffered QF: RAM buffer + one-pass merge to flash)",
        cfg_cls=BufferedQFConfig,
        make=make,
        insert=insert,
        contains=contains,
        stats=stats,
        delete=delete,
        merge=merge,
        probe=probe,
        needs_resize=needs_resize,
        grow=grow,
        resize=resize,
        needs_shrink=needs_shrink,
        shrink=shrink,
    )
)
