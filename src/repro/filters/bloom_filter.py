"""Bloom-filter family under the functional protocol (paper §2).

* ``bloom`` — classic k-hash Bloom filter (double hashing).  With
  ``counting=True`` the cells are 8-bit counters, enabling ``delete``
  and exact ``merge`` (counter addition); the plain variant merges by
  bitwise OR and does not register ``delete``.
* ``blocked_bloom`` — hash-localized variant: all k probes of a key
  land in one ``block_bits``-sized region (one cache line / flash page),
  the in-RAM analogue of the paper's buffered Bloom filter [Canim et
  al.].  Slightly worse FP rate, one-page lookups.

The state is a :class:`BloomState` pytree: the cell array (uint8 bits /
uint16 counting cells, so a key inserted up to 64k times or a large
merge cannot wrap a counter into a false negative; space is *accounted*
at the paper's 4 bits per counter regardless) plus an int32 insert
count driving the resize predicate.  As with any counting Bloom filter,
deleting a key that was never inserted corrupts the shared counters —
don't.

Growth: a Bloom filter cannot be rebuilt at a new size without the
original keys, but cell-count doubling *is* exact for membership:
``h mod 2m`` is congruent to ``h mod m`` (mod m), i.e. the new index of
any old key is its old index or its old index + m — tiling the cell
array twice therefore preserves every stored key (no false negatives,
and delete still finds a counter >= the true count).  The old region's
fill never dilutes, so unlike the QF family the FP rate does not
recover for old keys; growth buys headroom for *new* keys.  The resize
predicate is count-based (``n`` vs the classic ``m ln2 / k`` capacity),
which doubling resets — a fill-based predicate would never clear.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax.numpy as jnp

from repro.core import bloom
from repro.core.fingerprint import fmix32

from .registry import FilterImpl, register


class BloomFilterConfig(NamedTuple):
    m_bits: int
    k: int
    seed: int = 0
    counting: bool = False
    shrink_load: float = 0.4  # low watermark vs the folded (halved) tiling
    backend: str = "reference"  # "pallas" routes through kernels.ops

    @property
    def core(self) -> bloom.BloomConfig:
        return bloom.BloomConfig(
            m_bits=self.m_bits, k=self.k, seed=self.seed, counting=self.counting
        )


class BlockedBloomConfig(NamedTuple):
    m_bits: int
    k: int
    block_bits: int = 4096 * 8  # one 4 KiB page per key
    seed: int = 0
    counting: bool = False
    shrink_load: float = 0.4  # low watermark vs the folded (halved) tiling
    backend: str = "reference"  # "pallas" routes through kernels.ops

    @property
    def n_blocks(self) -> int:
        return max(1, self.m_bits // self.block_bits)

    @property
    def size_bytes(self) -> int:
        cells = self.n_blocks * self.block_bits
        return (cells * (4 if self.counting else 1) + 7) // 8


class BloomState(NamedTuple):
    cells: jnp.ndarray  # uint8 bits / uint16 counting cells
    n: jnp.ndarray  # int32 scalar, number of (valid) keys inserted


def _indices(cfg, keys: jnp.ndarray) -> jnp.ndarray:
    """(B, k) cell indices for either config flavor."""
    if isinstance(cfg, BloomFilterConfig):
        return bloom.bit_indices(cfg.core, keys)
    # blocked: block via an independent hash, k cells inside the block
    k32 = keys.astype(jnp.uint32)
    blk = fmix32(k32 ^ jnp.uint32(cfg.seed * 2 + 0xB10C)) % jnp.uint32(cfg.n_blocks)
    inner = bloom.bit_indices(
        bloom.BloomConfig(m_bits=cfg.block_bits, k=cfg.k, seed=cfg.seed), keys
    )
    return blk.astype(jnp.int32)[:, None] * cfg.block_bits + inner


def _cells(cfg) -> int:
    if isinstance(cfg, BloomFilterConfig):
        return cfg.m_bits
    return cfg.n_blocks * cfg.block_bits


def _count(keys, k) -> jnp.ndarray:
    return (
        jnp.int32(keys.shape[0]) if k is None else jnp.asarray(k, jnp.int32)
    )


def _masked(idx: jnp.ndarray, k) -> jnp.ndarray:
    """Route cells of invalid (padding) keys to an out-of-range slot."""
    if k is None:
        return idx
    valid = jnp.arange(idx.shape[0]) < jnp.asarray(k, jnp.int32)
    return jnp.where(valid[:, None], idx, jnp.int32(2**31 - 1))


def _cell_dtype(cfg):
    return jnp.uint16 if cfg.counting else jnp.uint8


def _capacity(cfg) -> int:
    """Design capacity: n = m ln2 / k keeps the fp rate near 2^-k."""
    return max(1, int(_cells(cfg) * math.log(2) / cfg.k))


def _check_backend(cfg) -> None:
    if cfg.backend not in ("reference", "pallas"):
        raise ValueError(
            f"backend must be 'reference' or 'pallas', got {cfg.backend!r}"
        )


def _kernel_mode(cfg):
    """Kernel mode for this config under the pallas backend.

    The bin kernels need the blocked layout's locality (all k probes in
    one bin); the classic Bloom filter's probes are table-wide random
    gathers with nothing to tile, so its pallas backend pins the
    kernel-equivalent xla lowering on every platform.
    """
    return None if isinstance(cfg, BlockedBloomConfig) else "xla"


def _use_bin_kernel(cfg) -> bool:
    """Whether insert/delete should go through the bin-count kernel.

    Only when the resolved mode is a real Pallas kernel (mosaic /
    interpret).  For a commutative scatter-accumulate the
    kernel-equivalent XLA lowering *is* the reference scatter itself,
    so under the xla mode the counts detour (which exists to mirror the
    kernel's per-tile count semantics) would just materialize an extra
    cell-sized plane for nothing."""
    from repro.kernels import dispatch

    return dispatch.is_pallas(dispatch.resolve(mode=_kernel_mode(cfg)))


def make_impl(cfg_cls, name: str, paper_section: str):
    def make(**spec):
        cfg = cfg_cls(**spec)
        _check_backend(cfg)
        return cfg, BloomState(
            cells=jnp.zeros((_cells(cfg),), _cell_dtype(cfg)),
            n=jnp.zeros((), jnp.int32),
        )

    def _counts(cfg, keys, k):
        """Per-cell hit counts of a masked batch via the bin kernel."""
        from repro.kernels import ops as kernel_ops

        idx = _masked(_indices(cfg, keys), k).reshape(-1)
        return kernel_ops.bloom_counts(idx, _cells(cfg), mode=_kernel_mode(cfg))

    def insert(cfg, state, keys, k=None):
        if cfg.backend == "pallas" and _use_bin_kernel(cfg):
            counts = _counts(cfg, keys, k)
            if cfg.counting:
                # uint16 add wraps exactly like the reference's repeated +1
                cells = state.cells + counts.astype(jnp.uint16)
            else:
                cells = jnp.maximum(state.cells, (counts > 0).astype(jnp.uint8))
            return BloomState(cells=cells, n=state.n + _count(keys, k))
        idx = _masked(_indices(cfg, keys), k).reshape(-1)
        if cfg.counting:
            cells = state.cells.at[idx].add(jnp.uint16(1), mode="drop")
        else:
            cells = state.cells.at[idx].max(jnp.uint8(1), mode="drop")
        return BloomState(cells=cells, n=state.n + _count(keys, k))

    def contains(cfg, state, keys):
        idx = _indices(cfg, keys)
        if cfg.backend == "pallas":
            from repro.kernels import ops as kernel_ops

            return kernel_ops.bloom_probe(state.cells, idx, mode=_kernel_mode(cfg))
        return jnp.all(state.cells[idx] > 0, axis=1)

    def delete(cfg, state, keys, k=None):
        if not cfg.counting:
            raise NotImplementedError(
                f"{name}: delete requires counting=True (plain bits can't unset)"
            )
        if cfg.backend == "pallas" and _use_bin_kernel(cfg):
            counts = _counts(cfg, keys, k)
            # wrapping subtract == the reference's per-copy add(0xFFFF)
            cells = state.cells - counts.astype(jnp.uint16)
            return BloomState(cells=cells, n=state.n - _count(keys, k))
        idx = _masked(_indices(cfg, keys), k).reshape(-1)
        cells = state.cells.at[idx].add(jnp.uint16(0xFFFF), mode="drop")  # wrapping -1
        return BloomState(cells=cells, n=state.n - _count(keys, k))

    def merge(cfg, sa, sb):
        if cfg.counting:
            cells = sa.cells + sb.cells
        else:
            cells = jnp.maximum(sa.cells, sb.cells)
        return BloomState(cells=cells, n=sa.n + sb.n)

    def needs_resize(cfg, state):
        return state.n >= jnp.int32(_capacity(cfg))

    def grow(cfg, state):
        """Double the cell array by tiling it (membership-exact, see
        module docstring); the config's cell count doubles to match."""
        if isinstance(cfg, BloomFilterConfig):
            new_cfg = cfg._replace(m_bits=2 * cfg.m_bits)
        else:
            # pin m_bits to the exact cell count so n_blocks doubles even
            # when the original m_bits was not a multiple of block_bits
            new_cfg = cfg._replace(m_bits=2 * cfg.n_blocks * cfg.block_bits)
        return new_cfg, state._replace(
            cells=jnp.concatenate([state.cells, state.cells])
        )

    def resize(cfg, state, factor: int = 2):
        """Grow by a power-of-two factor (shrinking would lose keys)."""
        if factor < 1 or factor & (factor - 1):
            raise ValueError("bloom resize factor must be a power of two >= 1")
        while factor > 1:
            cfg, state = grow(cfg, state)
            factor //= 2
        return cfg, state

    def _can_fold(cfg) -> bool:
        # folding halves the tiling: need an even cell count and a
        # remaining array the hash arithmetic can still index
        cells = _cells(cfg)
        if isinstance(cfg, BlockedBloomConfig):
            return cfg.n_blocks >= 2 and cfg.n_blocks % 2 == 0
        return cells % 2 == 0 and cells // 2 >= max(64, cfg.k)

    def needs_shrink(cfg, state):
        if not _can_fold(cfg):
            return jnp.zeros((), jnp.bool_)
        half_capacity = max(1, int(_cells(cfg) // 2 * math.log(2) / cfg.k))
        return state.n <= jnp.int32(cfg.shrink_load * half_capacity)

    def shrink(cfg, state):
        """Halve the cell array by folding the two tiles together —
        the exact inverse of ``grow``'s tiling: ``h mod m`` and
        ``h mod 2m`` agree mod ``m``, so OR-ing (or adding, for
        counting cells) the halves preserves every stored key: no
        false negatives, and a counter still bounds the true count.
        Old keys' fill concentrates (fp rate worsens toward the
        pre-growth point); the count-based predicate keeps that inside
        the design envelope."""
        if not _can_fold(cfg):
            raise ValueError(f"{name}: cell tiling cannot fold below this size")
        half = _cells(cfg) // 2
        lo, hi = state.cells[:half], state.cells[half:]
        if cfg.counting:
            folded = jnp.minimum(
                lo.astype(jnp.uint32) + hi.astype(jnp.uint32), jnp.uint32(0xFFFF)
            ).astype(jnp.uint16)
        else:
            folded = jnp.maximum(lo, hi)
        if isinstance(cfg, BloomFilterConfig):
            new_cfg = cfg._replace(m_bits=half)
        else:
            new_cfg = cfg._replace(m_bits=(cfg.n_blocks // 2) * cfg.block_bits)
        return new_cfg, state._replace(cells=folded)

    def stats(cfg, state):
        return {
            "n": state.n,
            "cells_set": jnp.sum((state.cells > 0).astype(jnp.int32)),
            "fill": jnp.mean((state.cells > 0).astype(jnp.float32)),
            "load": state.n.astype(jnp.float32) / _capacity(cfg),
            "size_bytes": cfg.size_bytes
            if hasattr(cfg, "size_bytes")
            else cfg.core.size_bytes,
        }

    return register(
        FilterImpl(
            name=name,
            paper_section=paper_section,
            cfg_cls=cfg_cls,
            make=make,
            insert=insert,
            contains=contains,
            stats=stats,
            delete=delete,
            merge=merge,
            needs_resize=needs_resize,
            grow=grow,
            resize=resize,
            needs_shrink=needs_shrink,
            shrink=shrink,
            can_delete=lambda cfg: cfg.counting,  # plain bits can't unset
        )
    )


BLOOM = make_impl(
    BloomFilterConfig, "bloom", "§2 (Bloom filter baseline; counting variant [3])"
)
BLOCKED_BLOOM = make_impl(
    BlockedBloomConfig,
    "blocked_bloom",
    "§2 (hash localization — buffered Bloom filter, Canim et al.)",
)
