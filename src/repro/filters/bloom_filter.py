"""Bloom-filter family under the functional protocol (paper §2).

* ``bloom`` — classic k-hash Bloom filter (double hashing).  With
  ``counting=True`` the cells are 8-bit counters, enabling ``delete``
  and exact ``merge`` (counter addition); the plain variant merges by
  bitwise OR and does not register ``delete``.
* ``blocked_bloom`` — hash-localized variant: all k probes of a key
  land in one ``block_bits``-sized region (one cache line / flash page),
  the in-RAM analogue of the paper's buffered Bloom filter [Canim et
  al.].  Slightly worse FP rate, one-page lookups.

States are bare cell arrays — already pytrees, fully jittable: uint8
for plain bits, uint16 for counting cells (so a key inserted up to 64k
times or a large merge cannot wrap a counter into a false negative;
space is *accounted* at the paper's 4 bits per counter regardless).
As with any counting Bloom filter, deleting a key that was never
inserted corrupts the shared counters — don't.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import bloom
from repro.core.fingerprint import fmix32

from .registry import FilterImpl, register


class BloomFilterConfig(NamedTuple):
    m_bits: int
    k: int
    seed: int = 0
    counting: bool = False

    @property
    def core(self) -> bloom.BloomConfig:
        return bloom.BloomConfig(
            m_bits=self.m_bits, k=self.k, seed=self.seed, counting=self.counting
        )


class BlockedBloomConfig(NamedTuple):
    m_bits: int
    k: int
    block_bits: int = 4096 * 8  # one 4 KiB page per key
    seed: int = 0
    counting: bool = False

    @property
    def n_blocks(self) -> int:
        return max(1, self.m_bits // self.block_bits)

    @property
    def size_bytes(self) -> int:
        cells = self.n_blocks * self.block_bits
        return (cells * (4 if self.counting else 1) + 7) // 8


def _indices(cfg, keys: jnp.ndarray) -> jnp.ndarray:
    """(B, k) cell indices for either config flavor."""
    if isinstance(cfg, BloomFilterConfig):
        return bloom.bit_indices(cfg.core, keys)
    # blocked: block via an independent hash, k cells inside the block
    k32 = keys.astype(jnp.uint32)
    blk = fmix32(k32 ^ jnp.uint32(cfg.seed * 2 + 0xB10C)) % jnp.uint32(cfg.n_blocks)
    inner = bloom.bit_indices(
        bloom.BloomConfig(m_bits=cfg.block_bits, k=cfg.k, seed=cfg.seed), keys
    )
    return blk.astype(jnp.int32)[:, None] * cfg.block_bits + inner


def _cells(cfg) -> int:
    if isinstance(cfg, BloomFilterConfig):
        return cfg.m_bits
    return cfg.n_blocks * cfg.block_bits


def _masked(idx: jnp.ndarray, k) -> jnp.ndarray:
    """Route cells of invalid (padding) keys to an out-of-range slot."""
    if k is None:
        return idx
    valid = jnp.arange(idx.shape[0]) < jnp.asarray(k, jnp.int32)
    return jnp.where(valid[:, None], idx, jnp.int32(2**31 - 1))


def _cell_dtype(cfg):
    return jnp.uint16 if cfg.counting else jnp.uint8


def make_impl(cfg_cls, name: str, paper_section: str):
    def make(**spec):
        cfg = cfg_cls(**spec)
        return cfg, jnp.zeros((_cells(cfg),), _cell_dtype(cfg))

    def insert(cfg, state, keys, k=None):
        idx = _masked(_indices(cfg, keys), k).reshape(-1)
        if cfg.counting:
            return state.at[idx].add(jnp.uint16(1), mode="drop")
        return state.at[idx].max(jnp.uint8(1), mode="drop")

    def contains(cfg, state, keys):
        idx = _indices(cfg, keys)
        return jnp.all(state[idx] > 0, axis=1)

    def delete(cfg, state, keys, k=None):
        if not cfg.counting:
            raise NotImplementedError(
                f"{name}: delete requires counting=True (plain bits can't unset)"
            )
        idx = _masked(_indices(cfg, keys), k).reshape(-1)
        return state.at[idx].add(jnp.uint16(0xFFFF), mode="drop")  # wrapping -1

    def merge(cfg, sa, sb):
        if cfg.counting:
            return sa + sb
        return jnp.maximum(sa, sb)

    def stats(cfg, state):
        return {
            "cells_set": jnp.sum((state > 0).astype(jnp.int32)),
            "fill": jnp.mean((state > 0).astype(jnp.float32)),
            "size_bytes": cfg.size_bytes if hasattr(cfg, "size_bytes") else cfg.core.size_bytes,
        }

    return register(
        FilterImpl(
            name=name,
            paper_section=paper_section,
            cfg_cls=cfg_cls,
            make=make,
            insert=insert,
            contains=contains,
            stats=stats,
            delete=delete,
            merge=merge,
            can_delete=lambda cfg: cfg.counting,  # plain bits can't unset
        )
    )


BLOOM = make_impl(
    BloomFilterConfig, "bloom", "§2 (Bloom filter baseline; counting variant [3])"
)
BLOCKED_BLOOM = make_impl(
    BlockedBloomConfig,
    "blocked_bloom",
    "§2 (hash localization — buffered Bloom filter, Canim et al.)",
)
