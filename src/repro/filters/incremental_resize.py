"""Incremental (amortized) resize: growth without the stop-the-world pass.

``resize``/``grow`` on the QF family re-streams the whole table in one
blocking device pass — exactly the "giant rebuild" the paper tells
flash stores to avoid.  This module amortizes that pass: ``begin``
freezes the old structure as its decoded sorted fingerprint stream (a
QF *is* a sorted multiset, §3) and allocates the wider table empty;
every subsequent ``insert`` then moves one bounded chunk of quotient
runs across and ``contains`` consults both structures, so no single
operation ever pays more than a chunk.

The key structural fact making the chunk step O(chunk) instead of
O(table): requotienting is monotone, so the migration stream arrives in
the *new* table's sorted order and the new planes are built strictly
left to right by ``kernels.ops.build_chunk`` — a carried ``cummax``
scan plus a handful of scattered slot writes, never a rebuild.  Fresh
inserts that arrive mid-migration cannot enter the frozen prefix, so
they land in a small side-buffer QF (the paper's RAM-buffer trick from
§4 applied to resizing); ``finish`` folds the buffer in with one
sort-free two-stream merge once the source is drained.

The in-flight migration is itself a registered (non-public) filter: the
façade's ``insert``/``contains``/``stats`` dispatch on
:class:`MigratingQFConfig` like any other family, so ingest drivers and
serving callers hold an opaque ``(cfg, state)`` pair throughout.  All
per-batch work is jittable device arithmetic — the only host decisions
(start a migration, collapse it when done) live in the
``filters.auto_scale`` driver, at the same one-sync-per-batch cadence
as ``auto_grow``.

Membership is exact at every cursor position: entries ``[0, cursor)``
of the stream live in the new planes, entries ``[cursor, n)`` answer
from a binary search of the frozen stream suffix, and mid-migration
inserts answer from the buffer — ``contains`` is the OR of the three,
so there are no false negatives (and no extra false positives either:
all three hold disjoint slices of one fingerprint multiset).

I/O accounting: each chunk charges its own chunk-sized sequential read
(old layout) and write (new layout) plus a ``migrate_chunks`` tick in
:class:`IOCounters` — the paper's amortized re-stream schedule, charged
where it happens instead of as one spike.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quotient_filter as qf
from repro.kernels import ops as kops

from . import iostats, qf_filter
from .iostats import IOCounters
from .qf_filter import QFilterConfig
from .registry import FilterImpl, by_cfg, register


class MigratingQFConfig(NamedTuple):
    """Static config of an in-flight QF migration (jit-static, hashable).

    ``wrap`` routes *other* families through the same chunked machinery:
    when set, it is the family config (steady / buffered / cascade) the
    drained flat table re-wraps into at :func:`finish` — the buffered
    QF's disk re-stream, the cascade's level-geometry change, and the
    steady QF's growth all migrate as their flat fingerprint stream and
    only the cheap re-wrap happens at settle time.  ``src_len`` pins the
    stream-plane length when the source is a multi-structure fold
    (longer than one table's slot count); 0 means the flat source's."""

    src: QFilterConfig  # old geometry (the frozen stream's split)
    dst: QFilterConfig  # wider geometry being built left-to-right
    buf: QFilterConfig  # small side buffer absorbing fresh inserts
    chunk: int = 1024  # entries moved per insert batch
    wrap: tuple | None = None  # family cfg to re-wrap into at finish
    src_len: int = 0  # stream length override (0 = src slots)


class MigrationState(NamedTuple):
    """Device state: frozen source stream + partial target + buffer."""

    src_fq: jnp.ndarray  # int32[src_slots] sorted quotients (src split)
    src_fr: jnp.ndarray  # uint32[src_slots] matching remainders
    src_n: jnp.ndarray  # int32 scalar: valid prefix of the stream
    cursor: jnp.ndarray  # int32 scalar: entries [cursor, src_n) still pending
    dst: qf.QFState  # holds exactly the entries [0, cursor)
    last_pos: jnp.ndarray  # int32 carry for build_chunk (-1 initially)
    last_fq: jnp.ndarray  # int32 carry for build_chunk (-1 initially)
    buf: qf.QFState  # fresh inserts that arrived mid-migration
    io: IOCounters


def _default_buf_q(cfg: QFilterConfig) -> int:
    # 8x smaller than the source table (floor 2^8): buffer ops stay well
    # under the table cost, and fresh inserts arriving at up to chunk/8
    # keys per batch fit for the whole drain (the driver settles early
    # on the buffer-full predicate if a workload outruns that)
    return max(8, cfg.q - 3)


def begin(
    cfg: QFilterConfig,
    state: qf.QFState,
    new_q: int | None = None,
    chunk: int = 1024,
    buf_q: int | None = None,
):
    """Freeze ``(cfg, state)`` and open a migration to ``new_q`` bits.

    Host-level (allocates the wider planes and the stream arrays) but
    cheap: one decode pass over the old table — no sort, no rebuild.
    Returns the opaque ``(MigratingQFConfig, MigrationState)`` pair.
    """
    if new_q is None:
        new_q = cfg.q + 1
    new_r = cfg.q + cfg.r - new_q
    if not (cfg.q < new_q <= 30 and new_r >= 1):
        raise ValueError(
            f"cannot migrate q={cfg.q} to q={new_q} within p={cfg.q + cfg.r}"
        )
    if chunk < 1:
        raise ValueError("chunk must be positive")
    if buf_q is None:
        buf_q = _default_buf_q(cfg)
    dst = cfg._replace(q=new_q, r=new_r)
    buf = cfg._replace(q=buf_q, r=cfg.q + cfg.r - buf_q)
    mcfg = MigratingQFConfig(src=cfg, dst=dst, buf=buf, chunk=chunk)
    src_fq, src_fr, src_n = qf.extract(cfg.core, state)
    io = iostats.zeros()._replace(resizes=jnp.ones((), jnp.int32))
    ms = MigrationState(
        src_fq=src_fq,
        src_fr=src_fr,
        src_n=src_n,
        cursor=jnp.zeros((), jnp.int32),
        dst=qf.empty(dst.core)._replace(overflow=state.overflow),
        last_pos=jnp.full((), -1, jnp.int32),
        last_fq=jnp.full((), -1, jnp.int32),
        buf=qf.empty(buf.core),
        io=io,
    )
    return mcfg, ms


def begin_stream(
    src: QFilterConfig,
    fq,
    fr,
    n,
    dst: QFilterConfig,
    *,
    chunk: int = 1024,
    buf_q: int | None = None,
    wrap=None,
    io: IOCounters | None = None,
):
    """Open a migration from an already-decoded sorted stream.

    The generic entry point behind :func:`begin_restructure`: the stream
    may be the fold of several structures (buffered RAM+disk, all
    cascade levels, a settled steady table), so its length is pinned in
    the config (``src_len``) rather than derived from one table."""
    if chunk < 1:
        raise ValueError("chunk must be positive")
    if buf_q is None:
        buf_q = _default_buf_q(dst)
    buf = dst._replace(q=buf_q, r=dst.q + dst.r - buf_q)
    mcfg = MigratingQFConfig(
        src=src,
        dst=dst,
        buf=buf,
        chunk=chunk,
        wrap=wrap,
        src_len=int(fq.shape[0]),
    )
    base = iostats.zeros() if io is None else io
    ms = MigrationState(
        src_fq=jnp.asarray(fq, jnp.int32),
        src_fr=jnp.asarray(fr, jnp.uint32),
        src_n=jnp.asarray(n, jnp.int32),
        cursor=jnp.zeros((), jnp.int32),
        dst=qf.empty(dst.core),
        last_pos=jnp.full((), -1, jnp.int32),
        last_fq=jnp.full((), -1, jnp.int32),
        buf=qf.empty(buf.core),
        io=base._replace(resizes=base.resizes + 1),
    )
    return mcfg, ms


def _flat_of(core: qf.QFConfig, template) -> QFilterConfig:
    """A QFilterConfig whose ``.core`` is exactly ``core`` (backend and
    probe window carried over from the family config ``template``)."""
    return QFilterConfig(
        q=core.q,
        r=core.r,
        slack=core.slack,
        seed=core.seed,
        max_load=core.max_load,
        backend=template.backend,
    )


def grows_by_migration(cfg) -> bool:
    """Families whose *growth* step re-streams data (and so should take
    the chunked path under ``auto_scale``).  The cascade is excluded:
    its ``grow`` appends an empty level — free — and only its explicit
    geometry ``resize`` migrates (via :func:`begin_restructure`)."""
    from . import buffered, steady

    return isinstance(
        cfg, (QFilterConfig, steady.SteadyQFConfig, buffered.BufferedQFConfig)
    )


def can_migrate(cfg) -> bool:
    """Does this family config have an incremental restructure path?"""
    from . import buffered, cascade, steady

    return isinstance(
        cfg,
        (
            QFilterConfig,
            steady.SteadyQFConfig,
            buffered.BufferedQFConfig,
            cascade.CascadeConfig,
        ),
    )


def begin_restructure(cfg, state, *, chunk: int = 1024, buf_q=None, **target):
    """Open a chunked migration for ANY family with a restructure path.

    One decode/fold pass (no sort, no rebuild) per family:

    * flat QF — :func:`begin` unchanged (``new_q``);
    * steady QF — settle, then migrate the table to ``new_q``; the
      drained table re-wraps as an idle steady state (``new_q``);
    * buffered QF — RAM and disk fold into one disk-split stream that
      migrates to the wider disk geometry (``disk_q``) — the disk
      re-stream, amortized;
    * cascade — every level (frozen ones from their retained runs)
      folds into one canonical stream migrating toward the new
      geometry's fitting level (``levels``/``fanout``); a frozen target
      peels once on device at re-wrap time.

    Returns the opaque ``(MigratingQFConfig, MigrationState)`` pair.
    """
    from . import buffered, cascade, steady

    if isinstance(cfg, QFilterConfig):
        return begin(
            cfg, state, new_q=target.pop("new_q", None), chunk=chunk, buf_q=buf_q
        )
    if isinstance(cfg, steady.SteadyQFConfig):
        state = steady.settle_all(cfg, state)
        new_q = target.pop("new_q", cfg.q + 1)
        flat_cfg, flat = cfg.flat, state.table
        dst_core = flat_cfg._replace(q=new_q, r=cfg.q + cfg.r - new_q).core
        wrap = steady._resolve_buf_q(
            cfg._replace(q=new_q, r=cfg.q + cfg.r - new_q, buf_q=0)
        )
        steady._check_geometry(wrap)
        fq, fr, n = qf.extract(flat_cfg.core, flat)
        return begin_stream(
            flat_cfg,
            fq,
            fr,
            n,
            _flat_of(dst_core, cfg),
            chunk=chunk,
            buf_q=buf_q,
            wrap=wrap,
            io=state.io,
        )
    if isinstance(cfg, buffered.BufferedQFConfig):
        disk_q = target.pop("disk_q", cfg.disk_q + 1)
        wrap = cfg._replace(disk_q=disk_q)
        if not (wrap.ram_q < disk_q < wrap.p):
            raise ValueError(
                f"disk_q={disk_q} must lie strictly between ram_q={cfg.ram_q} "
                f"and p={cfg.p}"
            )
        dq, dr, dn = qf.extract(cfg.disk, state.disk)
        rq, rr, rn = qf.extract(cfg.ram, state.ram)
        rq, rr = qf._requotient(rq, rr, cfg.ram, cfg.disk)
        fq, fr, n = qf.merge_streams_many([(dq, dr, dn), (rq, rr, rn)])
        io = state.io._replace(
            seq_read_bytes=state.io.seq_read_bytes + jnp.float32(cfg.disk.size_bytes)
        )
        return begin_stream(
            _flat_of(cfg.disk, cfg),
            fq,
            fr,
            n,
            _flat_of(wrap.disk, cfg),
            chunk=chunk,
            buf_q=buf_q,
            wrap=wrap,
            io=io,
        )
    if isinstance(cfg, cascade.CascadeConfig):
        wrap = cfg._replace(
            levels=target.pop("levels", cfg.levels),
            fanout=target.pop("fanout", cfg.fanout),
        )
        cascade._check_geometry(wrap)
        parts, read, overflow = cascade._all_streams(cfg, state)
        fq, fr, n = qf.merge_streams_many(parts)
        tgt = _cascade_target(wrap, int(jax.device_get(n)))
        io = state.io._replace(
            seq_read_bytes=state.io.seq_read_bytes + jnp.float32(read)
        )
        mcfg, ms = begin_stream(
            _flat_of(cascade._canon_cfg(cfg), cfg),
            fq,
            fr,
            n,
            _flat_of(wrap.level_cfg(tgt), cfg),
            chunk=chunk,
            buf_q=buf_q,
            wrap=wrap,
            io=io,
        )
        if overflow:
            ms = ms._replace(dst=ms.dst._replace(overflow=jnp.asarray(True)))
        return mcfg, ms
    raise TypeError(f"{type(cfg).__name__} has no incremental restructure path")


def _cascade_target(wrap, total: int) -> int:
    """Smallest level of the new geometry that fits the union count."""
    return next(
        (i for i in range(wrap.levels) if total <= wrap.level_cfg(i).capacity),
        wrap.levels - 1,
    )


def _rewrap(mcfg: MigratingQFConfig, state: qf.QFState, io: IOCounters):
    """Re-wrap the drained flat table as the target family's state."""
    from . import buffered, cascade, steady

    wrap = mcfg.wrap
    if isinstance(wrap, steady.SteadyQFConfig):
        return wrap, steady.from_flat(wrap, state, io=io)
    if isinstance(wrap, buffered.BufferedQFConfig):
        io = io._replace(
            seq_write_bytes=io.seq_write_bytes + jnp.float32(wrap.disk.size_bytes)
        )
        return wrap, buffered.BufferedQFState(
            ram=qf.empty(wrap.ram), disk=state, io=io
        )
    if isinstance(wrap, cascade.CascadeConfig):
        tgt = _cascade_target(wrap, int(state.n))
        io = io._replace(
            seq_write_bytes=io.seq_write_bytes
            + jnp.float32(cascade._level_write_bytes(wrap, tgt)),
            merges=io.merges + 1,
        )
        if wrap.is_frozen(tgt):
            fq, fr, n = qf.extract(mcfg.dst.core, state)
            fq, fr = qf._requotient(fq, fr, mcfg.dst.core, cascade._canon_cfg(wrap))
            merged = fuse_freeze(wrap, tgt, fq, fr, n, state.overflow)
        else:
            merged = state
        levels = tuple(
            merged if j == tgt else cascade._empty_level(wrap, j)
            for j in range(wrap.levels)
        )
        return wrap, cascade.CascadeState(
            q0=qf.empty(wrap.q0_cfg), levels=levels, io=io
        )
    raise TypeError(f"cannot re-wrap migration into {type(wrap).__name__}")


def fuse_freeze(wrap, i: int, fq, fr, n, overflow):
    """One device-resident peel of a canonical stream into frozen level
    ``i`` of cascade config ``wrap`` (the only non-chunkable step — the
    peel is a global algorithm — but a single fused device op)."""
    from repro.core import fuse_filter as fuse

    st = fuse.freeze_stream(wrap.fuse_cfg(i), fq, fr, n)
    return st._replace(overflow=st.overflow | overflow)


def blank(mcfg: MigratingQFConfig) -> MigrationState:
    """An all-zero state with this config's shapes (snapshot restore)."""
    t = mcfg.src_len or mcfg.src.core.total_slots
    return MigrationState(
        src_fq=jnp.full((t,), qf.INT32_MAX, jnp.int32),
        src_fr=jnp.full((t,), qf.UINT32_MAX, jnp.uint32),
        src_n=jnp.zeros((), jnp.int32),
        cursor=jnp.zeros((), jnp.int32),
        dst=qf.empty(mcfg.dst.core),
        last_pos=jnp.full((), -1, jnp.int32),
        last_fq=jnp.full((), -1, jnp.int32),
        buf=qf.empty(mcfg.buf.core),
        io=iostats.zeros(),
    )


def is_migrating(cfg) -> bool:
    return isinstance(cfg, MigratingQFConfig)


def _advance(mcfg: MigratingQFConfig, ms: MigrationState, steps: int = 1):
    """Move up to ``steps * chunk`` pending entries into the new planes.

    Pure device arithmetic with static shapes: a no-op (masked) once the
    stream is drained, so it is safe to call unconditionally per batch.

    The carried probe scan closed-forms over any span length, so a
    multi-step advance is ONE ``steps * chunk``-wide requotient + append
    (``kops.build_span`` — a single scatter / kernel grid), bit-identical
    to ``steps`` sequential chunk moves but without the host-composed
    per-chunk dispatch that used to dominate ``finish``-time drains.
    The I/O ledger still charges the *schedule* (one ``migrate_chunks``
    tick per chunk-sized slice moved), matching the per-step path.
    """
    src, dst = mcfg.src.core, mcfg.dst.core
    C = mcfg.chunk
    span = C * steps
    idx = ms.cursor + jnp.arange(span, dtype=jnp.int32)
    valid = idx < ms.src_n
    gi = jnp.clip(idx, 0, ms.src_fq.shape[0] - 1)
    fq = jnp.where(valid, ms.src_fq[gi], qf.INT32_MAX)
    fr = jnp.where(valid, ms.src_fr[gi], qf.UINT32_MAX)
    fq, fr = qf._requotient(fq, fr, src, dst)
    moved = jnp.sum(valid, dtype=jnp.int32)
    if steps == 1:
        # per-insert path: O(chunk) scattered writes on every backend
        new_dst, last_pos, last_fq = kops.build_chunk(
            dst, ms.dst, fq, fr, moved, ms.last_pos, ms.last_fq
        )
    else:
        new_dst, last_pos, last_fq = kops.build_span(
            dst, ms.dst, fq, fr, moved, ms.last_pos, ms.last_fq
        )
    io = ms.io._replace(
        seq_read_bytes=ms.io.seq_read_bytes
        + moved.astype(jnp.float32) * (src.bits_per_slot / 8.0),
        seq_write_bytes=ms.io.seq_write_bytes
        + moved.astype(jnp.float32) * (dst.bits_per_slot / 8.0),
        migrate_chunks=ms.io.migrate_chunks + (moved + C - 1) // C,
    )
    return ms._replace(
        cursor=ms.cursor + moved,
        dst=new_dst,
        last_pos=last_pos,
        last_fq=last_fq,
        io=io,
    )


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def _insert_step(mcfg: MigratingQFConfig, ms: MigrationState, keys, kk):
    ms = _advance(mcfg, ms)
    buf = qf_filter.insert_keys(mcfg.buf.core, mcfg.buf.backend, ms.buf, keys, kk)
    return ms._replace(buf=buf)


def insert(mcfg: MigratingQFConfig, ms: MigrationState, keys, k=None):
    """Migrate one chunk, then land the fresh keys in the side buffer.

    One fused jitted step with the state donated (XLA updates the
    partially built planes in place where the backend supports it), so
    the per-batch cost during a migration is the chunk move plus a
    small-buffer insert — never a full-table pass.  As with any donated
    op, callers must use the returned state, not the argument.

    Like any fixed-size QF, a batch exceeding the side buffer's slack
    trips its ``overflow`` flag (surfaced through ``stats`` and
    propagated by :func:`finish`) rather than growing mid-step;
    ``auto_scale`` settles the migration *before* inserting any batch
    the buffer could not absorb, so driver-fed ingest never gets there.
    """
    kk = jnp.asarray(keys.shape[0] if k is None else k, jnp.int32)
    return _insert_step(mcfg, ms, keys, kk)


def _suffix_hit(ms: MigrationState, fq, fr):
    """Does the not-yet-migrated stream suffix hold this fingerprint?"""
    lo = qf.lex_searchsorted(ms.src_fq, ms.src_fr, fq, fr, "left")
    hi = qf.lex_searchsorted(ms.src_fq, ms.src_fr, fq, fr, "right")
    return hi > jnp.maximum(lo, ms.cursor)


def contains(mcfg: MigratingQFConfig, ms: MigrationState, keys):
    """MAY-CONTAIN across all three slices — no false negatives at any
    cursor position (the migrated prefix answers from the new planes,
    the pending suffix from the stream, fresh keys from the buffer)."""
    fq_s, fr_s = qf.fingerprints(mcfg.src.core, keys)
    hit = _suffix_hit(ms, fq_s, fr_s)
    hit = hit | qf_filter.contains_keys(
        mcfg.dst.core, mcfg.dst.backend, ms.dst, keys, mcfg.dst.window
    )
    return hit | qf_filter.contains_keys(
        mcfg.buf.core, mcfg.buf.backend, ms.buf, keys, mcfg.buf.window
    )


def migration_done(mcfg: MigratingQFConfig, ms: MigrationState):
    """Device predicate: the frozen stream is fully drained."""
    return ms.cursor >= ms.src_n


def needs_settle(mcfg: MigratingQFConfig, ms: MigrationState):
    """Device predicate: the host should call :func:`finish` now —
    either the stream is drained or the side buffer is approaching its
    own capacity (fresh inserts outran the migration)."""
    buf_full = ms.buf.n >= jnp.int32(mcfg.buf.core.capacity)
    return migration_done(mcfg, ms) | buf_full


def finish(mcfg: MigratingQFConfig, ms: MigrationState):
    """Collapse the migration into a plain ``(cfg, state)`` QF pair.

    Drains any pending stream entries in ONE fused span append
    (``kops.build_span`` — usually zero entries by the time the driver
    calls this), then folds the side buffer in with one sort-free
    two-stream merge — O(table) scatter work, skipping the
    O(table log table) sort a blocking resize pays.
    """
    pending = int(ms.src_n - ms.cursor)
    if pending > 0:
        ms = _advance(mcfg, ms, steps=-(-pending // mcfg.chunk))
    dst_core = mcfg.dst.core
    if int(ms.buf.n) == 0:
        state = ms.dst
    else:
        dq, dr, dn = qf.extract(dst_core, ms.dst)
        bq, br, bn = qf.extract(mcfg.buf.core, ms.buf)
        bq, br = qf._requotient(bq, br, mcfg.buf.core, dst_core)
        allq, allr = qf.merge_streams(dq, dr, dn, bq, br, bn)
        build = qf_filter.build_fn(mcfg.dst)
        state = build(dst_core, allq, allr, dn + bn)
        state = state._replace(
            overflow=state.overflow | ms.dst.overflow | ms.buf.overflow
        )
    if mcfg.wrap is not None:
        return _rewrap(mcfg, state, ms.io)
    return mcfg.dst, state


# -- registry bindings (non-public: constructed by begin(), not by name) ----


def _make(**spec):
    """Open a migration directly from a flat-QF spec (conformance shim);
    real callers go through :func:`begin` via ``filters.auto_scale``."""
    new_q = spec.pop("new_q", None)
    chunk = spec.pop("chunk", 1024)
    buf_q = spec.pop("buf_q", None)
    cfg, state = qf_filter.make(**spec)
    return begin(cfg, state, new_q=new_q, chunk=chunk, buf_q=buf_q)


def _grow(mcfg: MigratingQFConfig, ms: MigrationState):
    """Settle, then take the (possibly re-wrapped) family's doubling step."""
    cfg, state = finish(mcfg, ms)
    return by_cfg(cfg).grow(cfg, state)


def _resize(mcfg: MigratingQFConfig, ms: MigrationState, **kw):
    cfg, state = finish(mcfg, ms)
    return by_cfg(cfg).resize(cfg, state, **kw)


def stats(mcfg: MigratingQFConfig, ms: MigrationState):
    return {
        "n": (ms.src_n - ms.cursor) + ms.dst.n + ms.buf.n,
        "migrating": jnp.ones((), jnp.bool_),
        "cursor": ms.cursor,
        "pending": ms.src_n - ms.cursor,
        "buffered": ms.buf.n,
        "load": (ms.dst.n + ms.buf.n + (ms.src_n - ms.cursor)).astype(jnp.float32)
        / mcfg.dst.core.m,
        "overflow": ms.dst.overflow | ms.buf.overflow,
        "size_bytes": mcfg.src.core.size_bytes
        + mcfg.dst.core.size_bytes
        + mcfg.buf.core.size_bytes,
        **ms.io._asdict(),
    }


IMPL = register(
    FilterImpl(
        name="migrating_qf",
        paper_section="§3 resizing, amortized (this repo's incremental variant)",
        cfg_cls=MigratingQFConfig,
        make=_make,
        insert=insert,
        contains=contains,
        stats=stats,
        needs_resize=needs_settle,
        grow=_grow,
        resize=_resize,
    ),
    public=False,
)
