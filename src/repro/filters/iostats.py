"""Device-side I/O accounting for the functional filter states.

The legacy dataclass filters (``core.buffered_qf``, ``core.cascade_filter``)
mutate a host-side :class:`repro.core.cost_model.IOLog`, which forces a
device->host sync on every insert batch.  :class:`IOCounters` keeps the
same schedule as scalars *inside* the filter state pytree, so a whole
ingest loop — flush/merge decisions included — runs under one
``jax.jit``/``jax.lax.scan`` with zero host transfers.  Convert to an
``IOLog`` (host) only at reporting time via :func:`to_iolog`.

Op counts are int32; byte counters are float32 (int64 is unavailable
without x64 mode and int32 would overflow at ~2 GB of modeled traffic).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.cost_model import IOLog


class IOCounters(NamedTuple):
    """Pytree of device scalars mirroring the fields of ``IOLog``.

    ``resizes`` (structural grow/resize passes; their streaming traffic
    is charged into the seq byte counters), ``migrate_chunks`` (bounded
    incremental-resize chunk moves, each charging its own chunk-sized
    seq read/write) and ``settles`` (background buffer folds — the
    LSM-style compaction ticks of the steady-state families) have no
    ``IOLog`` counterpart and are reported only through ``stats``.
    """

    rand_page_reads: jnp.ndarray  # int32
    rand_page_writes: jnp.ndarray  # int32
    seq_read_bytes: jnp.ndarray  # float32
    seq_write_bytes: jnp.ndarray  # float32
    flushes: jnp.ndarray  # int32
    merges: jnp.ndarray  # int32
    resizes: jnp.ndarray  # int32
    migrate_chunks: jnp.ndarray  # int32
    settles: jnp.ndarray  # int32


def zeros() -> IOCounters:
    # distinct buffers per field so a donated state never aliases itself
    return IOCounters(
        rand_page_reads=jnp.zeros((), jnp.int32),
        rand_page_writes=jnp.zeros((), jnp.int32),
        seq_read_bytes=jnp.zeros((), jnp.float32),
        seq_write_bytes=jnp.zeros((), jnp.float32),
        flushes=jnp.zeros((), jnp.int32),
        merges=jnp.zeros((), jnp.int32),
        resizes=jnp.zeros((), jnp.int32),
        migrate_chunks=jnp.zeros((), jnp.int32),
        settles=jnp.zeros((), jnp.int32),
    )


def add(a: IOCounters, b: IOCounters) -> IOCounters:
    return IOCounters(*(x + y for x, y in zip(a, b)))


def to_iolog(io: IOCounters) -> IOLog:
    """Host-side conversion for benchmarks / reporting (syncs the device)."""
    return IOLog(
        rand_page_reads=int(io.rand_page_reads),
        rand_page_writes=int(io.rand_page_writes),
        seq_read_bytes=int(io.seq_read_bytes),
        seq_write_bytes=int(io.seq_write_bytes),
        flushes=int(io.flushes),
        merges=int(io.merges),
    )
