"""Steady-state quotient filter: always-on write buffer + background settle.

The flat QF's ``insert`` rewrites a quotient run in place — O(cluster)
per batch, and the paper's whole point is that such in-place writes are
what thrash flash.  This family keeps the paper's RAM-buffer trick (§4)
permanently resident: every insert lands in a small buffer QF
(O(buffer) always), and the fold into the main table happens as
*background settle ticks* — the LSM compaction pattern applied to one
table.

A settle is the incremental-resize machinery turned on itself — and
even its *open* tick is O(buffer), not O(table):

* **open** — when the buffer crosses its watermark (``settle_load``)
  and no settle is running, only the *buffer* decodes (O(buffer)) into
  a small sorted stream; the table's own sorted stream is the
  ``out`` planes **retained from the previous settle** (the drain
  materializes the merged stream as it emits it), so no O(table)
  extract happens on the insert path.  Rare paths that mutate the
  table behind the planes' back (``delete``, a forced early settle,
  ``from_flat`` re-wraps) drop the ``clean`` flag and the next open
  pays one ``qf.extract`` inside a ``lax.cond`` branch.  The table
  planes then reset empty;
* **drain** — each subsequent insert rank-merges one bounded ``chunk``
  window of the two sorted streams (table stream + buffer stream;
  ``lex_searchsorted`` + scatter, sort-free — the k smallest entries
  of two sorted streams lie within the first k of each) and appends it
  via ``kernels.ops.build_chunk`` (strictly left-to-right; no
  requotient — both streams are kept in the table's (q, r) split).
  When the buffer refills faster than the drain retires the streams,
  ticks widen to ``pressure`` chunks (``kernels.ops.build_span``) so
  the settle always outruns the writer before the buffer can overflow.

Membership is exact at every cursor position, mirroring
``incremental_resize``: entries already drained answer from the
partial table, the pending suffixes ``[cursor, src_n)`` and
``[bcursor, bsrc_n)`` from binary searches of the two stream
suffixes, fresh keys from the buffer — ``contains`` ORs the disjoint
slices, so there are no false negatives mid-settle and no extra false
positives.

Structural ops (``delete``/``merge``/``resize``/``grow``/``shrink``)
settle fully first (one fused device pass — the only O(table) ops in
the family, all off the insert hot path); growth through
``filters.auto_scale`` routes the table through the chunked
``incremental_resize`` migration instead, so even a doubling never
blocks an insert.  ``IOCounters.settles`` counts the folds;
drain ticks charge the usual chunk-sized sequential bytes.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quotient_filter as qf
from repro.kernels import ops as kops

from . import iostats, qf_filter
from .iostats import IOCounters
from .qf_filter import QFilterConfig
from .registry import FilterImpl, register


class SteadyQFConfig(NamedTuple):
    """Flat-QF geometry plus the steady-state write-buffer knobs."""

    q: int
    r: int
    buf_q: int = 0  # write-buffer buckets; 0 = auto (max(8, q - 3))
    slack: int = 1024
    seed: int = 0
    max_load: float = 0.75
    backend: str = "reference"
    window: int = 256
    shrink_load: float = 0.4
    chunk: int = 256  # stream entries drained per insert tick
    settle_load: float = 0.5  # buffer load that opens a settle
    pressure: int = 8  # tick multiplier once the buffer is 3/4 full

    @property
    def flat(self) -> QFilterConfig:
        """The equivalent flat-QF config (structural ops delegate here)."""
        return QFilterConfig(
            q=self.q,
            r=self.r,
            slack=self.slack,
            seed=self.seed,
            max_load=self.max_load,
            backend=self.backend,
            window=self.window,
            shrink_load=self.shrink_load,
        )

    @property
    def table(self) -> qf.QFConfig:
        return self.flat.core

    @property
    def buf(self) -> qf.QFConfig:
        # the buffer re-splits the same p-bit fingerprints at buf_q, so
        # requotienting into the table split is lossless and monotone
        return qf.QFConfig(
            q=self.buf_q,
            r=self.q + self.r - self.buf_q,
            slack=max(64, self.slack // 8),
            seed=self.seed,
            max_load=self.max_load,
        )

    @property
    def stream_len(self) -> int:
        """Settle-stream length: a full table + buffer fold must fit."""
        return self.table.total_slots + self.buf.total_slots


class SteadyQFState(NamedTuple):
    """Pure pytree: main table + write buffer + in-flight settle streams.

    Invariant: every stream plane is a lexicographically sorted valid
    prefix followed by sentinel padding, so the ``contains`` suffix
    binary searches never see garbage.  ``out`` holds the merged stream
    the drain has emitted so far; once a settle completes it equals the
    table's sorted multiset and ``clean`` goes up — the next settle's
    open reads it back instead of paying an O(table) ``qf.extract``.
    """

    table: qf.QFState  # holds the drained stream prefix when settling
    buf: qf.QFState  # every fresh insert lands here first
    src_fq: jnp.ndarray  # int32[table slots]: table-side settle stream
    src_fr: jnp.ndarray  # uint32[table slots]
    src_n: jnp.ndarray  # int32 scalar: valid prefix of the table stream
    cursor: jnp.ndarray  # int32 scalar: [cursor, src_n) still pending
    bsrc_fq: jnp.ndarray  # int32[buf slots]: buffer-side settle stream
    bsrc_fr: jnp.ndarray  # uint32[buf slots] (already in the table split)
    bsrc_n: jnp.ndarray  # int32 scalar: valid prefix of the buffer stream
    bcursor: jnp.ndarray  # int32 scalar: [bcursor, bsrc_n) still pending
    out_fq: jnp.ndarray  # int32[table slots]: merged stream, drain-built
    out_fr: jnp.ndarray  # uint32[table slots]
    clean: jnp.ndarray  # bool scalar: out[:table.n] == sorted table
    last_pos: jnp.ndarray  # int32 build_chunk carry (-1 initially)
    last_fq: jnp.ndarray  # int32 build_chunk carry (-1 initially)
    io: IOCounters


def _resolve_buf_q(cfg: SteadyQFConfig) -> SteadyQFConfig:
    buf_q = cfg.buf_q or max(8, cfg.q - 3)
    return cfg._replace(buf_q=buf_q)


def _check_geometry(cfg: SteadyQFConfig) -> None:
    qf_filter._check_backend(cfg)
    if not (1 <= cfg.buf_q < cfg.q):
        raise ValueError(f"buf_q must be in [1, q), got {cfg.buf_q} vs q={cfg.q}")
    max_r = 31 if cfg.backend == "pallas" else 32
    if cfg.q + cfg.r - cfg.buf_q > max_r:
        raise ValueError(
            f"buffer remainder p - buf_q = {cfg.q + cfg.r - cfg.buf_q} "
            f"exceeds {max_r} bits; raise buf_q"
        )
    if cfg.chunk < 1 or cfg.pressure < 1:
        raise ValueError("chunk and pressure must be positive")
    if not (0.0 < cfg.settle_load <= 1.0):
        raise ValueError("settle_load must be in (0, 1]")


def _sentinel_planes(n: int):
    return (
        jnp.full((n,), qf.INT32_MAX, jnp.int32),
        jnp.full((n,), qf.UINT32_MAX, jnp.uint32),
    )


def from_flat(cfg: SteadyQFConfig, table: qf.QFState, io=None) -> SteadyQFState:
    """Wrap a settled flat-QF table as an idle steady state.

    The wrapped table's sorted planes are unknown, so ``clean`` is down
    (unless the table is empty — sentinels describe it exactly) and the
    first settle pays one extract."""
    fq, fr = _sentinel_planes(cfg.table.total_slots)
    # distinct buffers for the out planes: the jitted insert step donates
    # the state, and one buffer may not be donated twice
    ofq, ofr = _sentinel_planes(cfg.table.total_slots)
    bq, br = _sentinel_planes(cfg.buf.total_slots)
    return SteadyQFState(
        table=table,
        buf=qf.empty(cfg.buf),
        src_fq=fq,
        src_fr=fr,
        src_n=jnp.zeros((), jnp.int32),
        cursor=jnp.zeros((), jnp.int32),
        bsrc_fq=bq,
        bsrc_fr=br,
        bsrc_n=jnp.zeros((), jnp.int32),
        bcursor=jnp.zeros((), jnp.int32),
        out_fq=ofq,
        out_fr=ofr,
        clean=jnp.asarray(table.n == 0, jnp.bool_),
        last_pos=jnp.full((), -1, jnp.int32),
        last_fq=jnp.full((), -1, jnp.int32),
        io=iostats.zeros() if io is None else io,
    )


def make(**spec):
    cfg = _resolve_buf_q(SteadyQFConfig(**spec))
    _check_geometry(cfg)
    return cfg, from_flat(cfg, qf.empty(cfg.table))


# ---------------------------------------------------------------------------
# Settle machinery (all traceable; composed inside the jitted insert)
# ---------------------------------------------------------------------------


def _open_settle(cfg: SteadyQFConfig, s: SteadyQFState) -> SteadyQFState:
    """Arm the two settle streams; reset table and buffer planes.

    O(buffer): the buffer decodes (it is small by construction) and the
    table's sorted stream comes from the retained ``out`` planes of the
    previous settle.  Only when ``clean`` is down (the table was
    mutated directly — delete, forced settle, re-wrap) does the taken
    ``lax.cond`` branch pay the O(table) decode."""
    tq, tr = jax.lax.cond(
        s.clean,
        lambda st: (st.out_fq, st.out_fr),
        lambda st: qf.extract(cfg.table, st.table)[:2],
        s,
    )
    bq, br, bn = qf.extract(cfg.buf, s.buf)
    bq, br = qf._requotient(bq, br, cfg.buf, cfg.table)
    io = s.io._replace(
        flushes=s.io.flushes + 1,
        settles=s.io.settles + 1,
    )
    ofq, ofr = _sentinel_planes(cfg.table.total_slots)
    return SteadyQFState(
        table=qf.empty(cfg.table)._replace(overflow=s.table.overflow | s.buf.overflow),
        buf=qf.empty(cfg.buf),
        src_fq=tq,
        src_fr=tr,
        src_n=s.table.n,
        cursor=jnp.zeros((), jnp.int32),
        bsrc_fq=bq,
        bsrc_fr=br,
        bsrc_n=bn,
        bcursor=jnp.zeros((), jnp.int32),
        out_fq=ofq,
        out_fr=ofr,
        clean=jnp.zeros((), jnp.bool_),
        last_pos=jnp.full((), -1, jnp.int32),
        last_fq=jnp.full((), -1, jnp.int32),
        io=io,
    )


def _window(fq, fr, cursor, n, span):
    """Sentinel-padded gather of the next ``span`` pending entries."""
    idx = cursor + jnp.arange(span, dtype=jnp.int32)
    valid = idx < n
    gi = jnp.clip(idx, 0, fq.shape[0] - 1)
    wq = jnp.where(valid, fq[gi], qf.INT32_MAX)
    wr = jnp.where(valid, fr[gi], qf.UINT32_MAX)
    return wq, wr, jnp.sum(valid, dtype=jnp.int32)


def _merge_window(aq, ar, na, bq, br, nb, span: int):
    """Rank-merge two sorted sentinel-padded windows; count how many of
    each side land in the emitted ``span`` prefix (``merge_streams``'
    arithmetic, plus the consumed-split the cursors need)."""
    la, lb = aq.shape[0], bq.shape[0]
    ia = jnp.arange(la, dtype=jnp.int32)
    ib = jnp.arange(lb, dtype=jnp.int32)
    ra = ia + qf.lex_searchsorted(bq, br, aq, ar, "left")
    rb = ib + qf.lex_searchsorted(aq, ar, bq, br, "right")
    ra = jnp.where(ia < na, ra, nb + ia)
    rb = jnp.where(ib < nb, rb, la + ib)
    mq = jnp.full((la + lb,), qf.INT32_MAX, jnp.int32).at[ra].set(aq)
    mr = jnp.full((la + lb,), qf.UINT32_MAX, jnp.uint32).at[ra].set(ar)
    mq = mq.at[rb].set(bq)
    mr = mr.at[rb].set(br)
    adv_a = jnp.sum((ia < na) & (ra < span), dtype=jnp.int32)
    adv_b = jnp.sum((ib < nb) & (rb < span), dtype=jnp.int32)
    return mq[:span], mr[:span], adv_a, adv_b


def _drain(cfg: SteadyQFConfig, s: SteadyQFState, steps: int) -> SteadyQFState:
    """Merge up to ``steps * chunk`` pending stream entries into the table.

    One rank-merge of two chunk windows (the k smallest entries of two
    sorted streams lie within the first k of each) feeds the
    left-to-right append AND materializes into the ``out`` planes, so
    a completed settle leaves the table's sorted stream behind for the
    next open.  Masked no-op once drained, so it is safe to run
    unconditionally per insert."""
    span = cfg.chunk * steps
    aq, ar, na = _window(s.src_fq, s.src_fr, s.cursor, s.src_n, span)
    bq, br, nb = _window(s.bsrc_fq, s.bsrc_fr, s.bcursor, s.bsrc_n, span)
    mq, mr, adv_a, adv_b = _merge_window(aq, ar, na, bq, br, nb, span)
    moved = adv_a + adv_b
    append = kops.build_chunk if steps == 1 else kops.build_span
    table, last_pos, last_fq = append(
        cfg.table, s.table, mq, mr, moved, s.last_pos, s.last_fq
    )
    # materialize ONLY the emitted entries into the retained planes
    # (``merged[:moved]`` — the real entries sort ahead of the window
    # sentinels).  Lanes >= moved route out of range and drop: an idle
    # tick after ``settle_all`` reset the cursors to 0, so an unmasked
    # scatter would overwrite the retained prefix with sentinels
    done = s.cursor + s.bcursor
    lane = jnp.arange(span, dtype=jnp.int32)
    oi = jnp.where(lane < moved, done + lane, jnp.int32(s.out_fq.shape[0]))
    out_fq = s.out_fq.at[oi].set(mq, mode="drop")
    out_fr = s.out_fr.at[oi].set(mr, mode="drop")
    cursor = s.cursor + adv_a
    bcursor = s.bcursor + adv_b
    complete = (cursor >= s.src_n) & (bcursor >= s.bsrc_n)
    io = s.io._replace(
        seq_read_bytes=s.io.seq_read_bytes
        + moved.astype(jnp.float32) * (cfg.table.bits_per_slot / 8.0),
        seq_write_bytes=s.io.seq_write_bytes
        + moved.astype(jnp.float32) * (cfg.table.bits_per_slot / 8.0),
        migrate_chunks=s.io.migrate_chunks + (moved + cfg.chunk - 1) // cfg.chunk,
    )
    return s._replace(
        cursor=cursor,
        bcursor=bcursor,
        table=table,
        out_fq=out_fq,
        out_fr=out_fr,
        clean=s.clean | ((moved > 0) & complete),
        last_pos=last_pos,
        last_fq=last_fq,
        io=io,
    )


def _watermark(cfg: SteadyQFConfig) -> int:
    return max(1, int(cfg.settle_load * cfg.buf.capacity))


def _pressure_mark(cfg: SteadyQFConfig) -> int:
    return max(1, (3 * cfg.buf.capacity) // 4)


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def _insert_step(cfg: SteadyQFConfig, s: SteadyQFState, keys, kk) -> SteadyQFState:
    def _normal(st: SteadyQFState) -> SteadyQFState:
        # open a settle when the buffer crossed its watermark and the
        # previous stream is fully retired (settles never overlap) ...
        idle = (st.cursor >= st.src_n) & (st.bcursor >= st.bsrc_n)
        want = idle & (st.buf.n >= jnp.int32(_watermark(cfg)))
        st = jax.lax.cond(want, lambda x: _open_settle(cfg, x), lambda x: x, st)
        # ... run one background tick, widened under buffer pressure so
        # the drain outruns the writer ...
        st = jax.lax.cond(
            st.buf.n >= jnp.int32(_pressure_mark(cfg)),
            lambda x: _drain(cfg, x, cfg.pressure),
            lambda x: _drain(cfg, x, 1),
            st,
        )
        # ... then the insert itself: O(buffer), unconditionally
        buf = qf_filter.insert_keys(cfg.buf, cfg.backend, st.buf, keys, kk)
        return st._replace(buf=buf)

    def _forced(st: SteadyQFState) -> SteadyQFState:
        # the batch would overflow the buffer (dropping keys on the
        # floor): settle everything NOW and take the batch straight into
        # the table.  This is the early-settle escape hatch — exact for
        # any batch size, at stop-the-world cost, so size ``buf_q`` for
        # your batch if tail latency matters.
        st = _settle_body(cfg, st)
        table = qf_filter.insert_keys(cfg.table, cfg.backend, st.table, keys, kk)
        # the in-place insert bypassed the retained planes; this path is
        # already O(table), so re-extract here and keep the next settle
        # open O(buffer)
        ofq, ofr, _ = qf.extract(cfg.table, table)
        return st._replace(
            table=table,
            out_fq=ofq,
            out_fr=ofr,
            clean=jnp.ones((), jnp.bool_),
        )

    forced = s.buf.n + kk > jnp.int32(cfg.buf.capacity)
    return jax.lax.cond(forced, _forced, _normal, s)


def insert(cfg: SteadyQFConfig, state: SteadyQFState, keys, k=None):
    """O(buffer) insert + one bounded settle tick, as ONE jitted step.

    The state is donated (callers use the returned state); no call ever
    pays more than the buffer insert plus ``pressure * chunk`` stream
    moves — the flat QF's in-place run rewrite never happens here.
    """
    kk = jnp.asarray(keys.shape[0] if k is None else k, jnp.int32)
    return _insert_step(cfg, state, keys, kk)


def _suffix_hit(fq_plane, fr_plane, cursor, fq, fr):
    """Any occurrence of (fq, fr) in the still-pending stream suffix."""
    lo = qf.lex_searchsorted(fq_plane, fr_plane, fq, fr, "left")
    hi = qf.lex_searchsorted(fq_plane, fr_plane, fq, fr, "right")
    return hi > jnp.maximum(lo, cursor)


@functools.partial(jax.jit, static_argnums=(0,))
def contains(cfg: SteadyQFConfig, state: SteadyQFState, keys):
    """MAY-CONTAIN across the four disjoint slices (exact mid-settle)."""
    fq, fr = qf.fingerprints(cfg.table, keys)
    hit = _suffix_hit(state.src_fq, state.src_fr, state.cursor, fq, fr)
    hit = hit | _suffix_hit(state.bsrc_fq, state.bsrc_fr, state.bcursor, fq, fr)
    hit = hit | qf_filter.contains_keys(
        cfg.table, cfg.backend, state.table, keys, cfg.window
    )
    return hit | qf_filter.contains_keys(
        cfg.buf, cfg.backend, state.buf, keys, cfg.window
    )


def _settle_body(cfg: SteadyQFConfig, s: SteadyQFState) -> SteadyQFState:
    # drain whatever the streams still hold in ONE fused span append
    steps = -(-cfg.stream_len // cfg.chunk)
    pending = (s.src_n - s.cursor) + (s.bsrc_n - s.bcursor)
    busy = (pending > 0) | (s.buf.n > 0)
    s = _drain(cfg, s, steps)
    # fold the buffer in with one sort-free two-stream merge + rebuild
    tq, tr, tn = qf.extract(cfg.table, s.table)
    bq, br, bn = qf.extract(cfg.buf, s.buf)
    bq, br = qf._requotient(bq, br, cfg.buf, cfg.table)
    allq, allr = qf.merge_streams(tq, tr, tn, bq, br, bn)
    table = qf_filter.build_fn(cfg)(cfg.table, allq, allr, tn + bn)
    table = table._replace(overflow=table.overflow | s.table.overflow | s.buf.overflow)
    fq, fr = _sentinel_planes(cfg.table.total_slots)
    bfq, bfr = _sentinel_planes(cfg.buf.total_slots)
    T = cfg.table.total_slots
    io = s.io._replace(settles=s.io.settles + busy.astype(jnp.int32))
    return s._replace(
        table=table,
        buf=qf.empty(cfg.buf),
        src_fq=fq,
        src_fr=fr,
        src_n=jnp.zeros((), jnp.int32),
        cursor=jnp.zeros((), jnp.int32),
        bsrc_fq=bfq,
        bsrc_fr=bfr,
        bsrc_n=jnp.zeros((), jnp.int32),
        bcursor=jnp.zeros((), jnp.int32),
        # the merged stream IS the table's sorted contents: retain it so
        # the next open skips the extract (n <= capacity < total_slots)
        out_fq=allq[:T],
        out_fr=allr[:T],
        clean=jnp.ones((), jnp.bool_),
        last_pos=jnp.full((), -1, jnp.int32),
        last_fq=jnp.full((), -1, jnp.int32),
        io=io,
    )


@functools.partial(jax.jit, static_argnums=(0,))
def settle_all(cfg: SteadyQFConfig, state: SteadyQFState) -> SteadyQFState:
    """Retire the stream and fold the buffer — the table then holds the
    whole multiset.  O(table), used by the structural ops only."""
    return _settle_body(cfg, state)


def delete(cfg: SteadyQFConfig, state: SteadyQFState, keys, k=None):
    """Settle, then delete one copy per key from the table (exact)."""
    state = settle_all(cfg, state)
    fq, fr = qf.fingerprints(cfg.table, keys)
    table = qf_filter.delete_masked(
        cfg.table, state.table, fq, fr, qf_filter.valid_mask(keys, k)
    )
    # deletes are off the hot path (the settle above is already
    # O(table)): re-extract the retained planes now so the NEXT settle
    # open — which IS on the hot path — stays O(buffer)
    ofq, ofr, _ = qf.extract(cfg.table, table)
    return state._replace(
        table=table, out_fq=ofq, out_fr=ofr, clean=jnp.ones((), jnp.bool_)
    )


def merge(cfg: SteadyQFConfig, sa: SteadyQFState, sb: SteadyQFState):
    """Union of two steady filters (same cfg): settle both, merge tables."""
    sa = settle_all(cfg, sa)
    sb = settle_all(cfg, sb)
    core = cfg.table
    table = qf.merge(core, core, core, sa.table, sb.table)
    io = iostats.add(sa.io, sb.io)
    io = io._replace(merges=io.merges + 1)
    return from_flat(cfg, table, io=io)


def _total(state: SteadyQFState) -> jnp.ndarray:
    return (
        state.table.n
        + state.buf.n
        + (state.src_n - state.cursor)
        + (state.bsrc_n - state.bcursor)
    )


def needs_resize(cfg: SteadyQFConfig, state: SteadyQFState):
    """Device predicate: whole population at/over the table's max load."""
    return _total(state) >= jnp.int32(cfg.table.capacity)


def resize(cfg: SteadyQFConfig, state: SteadyQFState, new_q: int):
    """Settle, re-split the table at ``new_q``, re-wrap (host-level).

    ``buf_q`` re-derives from the new ``q`` unless it was pinned
    explicitly out of the auto band."""
    state = settle_all(cfg, state)
    flat_cfg, table = qf_filter.resize(cfg.flat, state.table, new_q)
    ncfg = _resolve_buf_q(
        cfg._replace(q=flat_cfg.q, r=flat_cfg.r, buf_q=0)
    )
    _check_geometry(ncfg)
    io = state.io._replace(resizes=state.io.resizes + 1)
    return ncfg, from_flat(ncfg, table, io=io)


def grow(cfg: SteadyQFConfig, state: SteadyQFState):
    return resize(cfg, state, cfg.q + 1)


def needs_shrink(cfg: SteadyQFConfig, state: SteadyQFState):
    if not qf_filter._can_halve(cfg.flat) or cfg.q - 1 <= cfg.buf_q:
        return jnp.zeros((), jnp.bool_)
    halved = cfg.table._replace(q=cfg.q - 1, r=cfg.r + 1)
    return _total(state) <= jnp.int32(cfg.shrink_load * halved.capacity)


def shrink(cfg: SteadyQFConfig, state: SteadyQFState):
    if not qf_filter._can_halve(cfg.flat):
        raise ValueError(f"cannot shrink q={cfg.q}, r={cfg.r} further")
    return resize(cfg, state, cfg.q - 1)


def stats(cfg: SteadyQFConfig, state: SteadyQFState):
    return {
        "n": _total(state),
        "load": _total(state).astype(jnp.float32) / cfg.table.m,
        "buffered": state.buf.n,
        "pending": (state.src_n - state.cursor) + (state.bsrc_n - state.bcursor),
        "settling": (state.cursor < state.src_n) | (state.bcursor < state.bsrc_n),
        "overflow": state.table.overflow | state.buf.overflow,
        "size_bytes": cfg.table.size_bytes + cfg.buf.size_bytes,
        **state.io._asdict(),
    }


IMPL = register(
    FilterImpl(
        name="steady_qf",
        paper_section="§4 RAM buffer, kept always-on (LSM-style steady state)",
        cfg_cls=SteadyQFConfig,
        make=make,
        insert=insert,
        contains=contains,
        stats=stats,
        delete=delete,
        merge=merge,
        needs_resize=needs_resize,
        grow=grow,
        resize=resize,
        needs_shrink=needs_shrink,
        shrink=shrink,
    )
)
