"""Mesh-sharded quotient filter under the functional protocol (paper §6).

Adapter over :mod:`repro.core.sharded_filter`: the state is the stacked
per-shard QF pytree, and insert/contains route keys to their owner
shard with the MoE-dispatch all_to_all schedule.  The shard_map'd step
functions are built lazily per (cfg, batch) and cached — the mesh is
derived from the visible devices (``n_shards`` must divide the device
count; ``n_shards=1`` works on a single host).

``delete`` is not registered: a deletion would need the same routed
dispatch plus per-shard multiset diffs, which the core module does not
expose yet.  ``merge`` is the per-shard pairwise QF merge (shard s owns
the same quotient range in both inputs).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quotient_filter as qf
from repro.core import sharded_filter as sf

from .registry import FilterImpl, register


class ShardedQFilterConfig(NamedTuple):
    q: int  # global log2 buckets
    r: int
    n_shards: int = 1
    axis: str = "data"
    seed: int = 0
    capacity_factor: float = 2.0
    shrink_load: float = 0.4  # low watermark for shard consolidation

    @property
    def core(self) -> sf.ShardedQFConfig:
        return sf.ShardedQFConfig(
            q=self.q,
            r=self.r,
            n_shards=self.n_shards,
            axis=self.axis,
            seed=self.seed,
            capacity_factor=self.capacity_factor,
        )


@functools.lru_cache(maxsize=None)
def _mesh(n_shards: int, axis: str):
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh((n_shards,), (axis,))
    # jax < 0.4.35
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh

    return Mesh(mesh_utils.create_device_mesh((n_shards,)), (axis,))


@functools.lru_cache(maxsize=None)
def _insert_fn(cfg: ShardedQFilterConfig, batch: int):
    core = cfg.core
    return jax.jit(sf.make_insert(core, _mesh(cfg.n_shards, cfg.axis), batch))


@functools.lru_cache(maxsize=None)
def _lookup_fn(cfg: ShardedQFilterConfig, batch: int):
    core = cfg.core
    return jax.jit(sf.make_lookup(core, _mesh(cfg.n_shards, cfg.axis), batch))


def _pad_batch(cfg, keys):
    """Pad to a multiple of n_shards (all_to_all needs equal splits)."""
    pad = (-keys.shape[0]) % cfg.n_shards
    if pad:
        keys = jnp.concatenate([keys, keys[:1].repeat(pad)])
    return keys, pad


def make(**spec):
    cfg = ShardedQFilterConfig(**spec)
    if cfg.n_shards & (cfg.n_shards - 1):
        raise ValueError("n_shards must be a power of two")
    if len(jax.devices()) % cfg.n_shards:
        raise ValueError(
            f"n_shards={cfg.n_shards} does not divide {len(jax.devices())} devices"
        )
    return cfg, sf.empty(cfg.core)


def insert(cfg: ShardedQFilterConfig, state, keys, k=None):
    if k is not None:
        raise NotImplementedError("sharded_qf insert does not take a valid count")
    if keys.shape[0] % cfg.n_shards:
        # padding would insert duplicate fingerprints (QF is a multiset)
        raise ValueError(
            f"insert batch ({keys.shape[0]}) must be a multiple of n_shards"
        )
    return _insert_fn(cfg, keys.shape[0])(state, keys)


def contains(cfg: ShardedQFilterConfig, state, keys):
    keys, pad = _pad_batch(cfg, keys)
    hit = _lookup_fn(cfg, keys.shape[0])(state, keys)
    return hit[: hit.shape[0] - pad] if pad else hit


def merge(cfg: ShardedQFilterConfig, sa, sb):
    local = cfg.core.local_cfg
    return jax.vmap(lambda a, b: qf.merge(local, local, local, a, b))(sa, sb)


def needs_resize(cfg: ShardedQFilterConfig, state):
    """Device predicate: global count at the paper's max-load point."""
    return jnp.sum(state.n) >= jnp.int32(cfg.core.local_cfg.capacity * cfg.n_shards)


def grow(cfg: ShardedQFilterConfig, state):
    """Per-shard growth: every shard steals one remainder bit, doubling
    the global bucket count while the quotient-prefix shard map is
    untouched (the owner bits are the *top* bits of the quotient).

    The stored local remainders are the global ``r`` real bits (the
    local config only declares the wider ``r + shard_bits`` slot so the
    shard id stays reconstructable), so the requotient must move the
    top bit of the *r-bit* remainder — the width-true split below, not
    ``local_cfg.r``.
    """
    if cfg.r <= 1:
        raise ValueError(
            f"cannot grow: fingerprint bits exhausted (q={cfg.q}, r={cfg.r})"
        )
    new_cfg = cfg._replace(q=cfg.q + 1, r=cfg.r - 1)
    lold, lnew = cfg.core.local_cfg, new_cfg.core.local_cfg
    win = lold._replace(r=cfg.r)
    wout = lnew._replace(r=cfg.r - 1)
    pad = lnew.total_slots - lold.total_slots

    def one(s):
        qs, rs, n = qf.extract(lold, s)
        qs, rs = qf._requotient(qs, rs, win, wout)
        qs = jnp.concatenate([qs, jnp.full((pad,), qf.INT32_MAX, jnp.int32)])
        rs = jnp.concatenate([rs, jnp.full((pad,), qf.UINT32_MAX, jnp.uint32)])
        new = qf.build_sorted(lnew, qs, rs, n)
        return new._replace(overflow=new.overflow | s.overflow)

    return new_cfg, jax.vmap(one)(state)


def resize(cfg: ShardedQFilterConfig, state, new_q: int):
    """Grow to ``new_q`` global quotient bits (shrinking the *table*
    would need per-slot re-merging across every shard; capacity comes
    back down by consolidating shards instead — see :func:`shrink`)."""
    if new_q < cfg.q:
        raise NotImplementedError(
            "sharded_qf tables only grow (new_q >= q); use shrink() to "
            "consolidate shards when load is low"
        )
    while cfg.q < new_q:
        cfg, state = grow(cfg, state)
    return cfg, state


def _can_halve(cfg: ShardedQFilterConfig) -> bool:
    # halving merges shard pairs AND re-merges one quotient bit into the
    # remainder (the inverse of grow): it needs an even pair count, a
    # surviving local table, and remainder headroom for the returned bit
    return (
        cfg.n_shards >= 2
        and cfg.n_shards % 2 == 0
        and cfg.q - cfg.core.shard_bits >= 2
        and cfg.r + cfg.core.shard_bits <= 32  # declared local width holds
    )


def needs_shrink(cfg: ShardedQFilterConfig, state):
    """Device predicate: the population fits the halved filter (half
    the shards AND half the global buckets) at the low watermark.

    Each shrink halves global capacity, so the threshold halves with
    it — real hysteresis: one quiet period consolidates one step, not
    the whole fleet, and the count must double again before the high
    watermark can trip."""
    if not _can_halve(cfg):
        return jnp.zeros((), jnp.bool_)
    halved = cfg._replace(q=cfg.q - 1, r=cfg.r + 1, n_shards=cfg.n_shards // 2)
    cap = halved.core.local_cfg.capacity * halved.n_shards
    return jnp.sum(state.n) <= jnp.int32(cfg.shrink_load * cap)


def shrink(cfg: ShardedQFilterConfig, state):
    """Halve the filter: shard pairs redistribute and a quotient bit
    re-merges into the remainder — the exact inverse of ``grow``.

    Dropping the global quotient's low bit sends it to the remainder
    top (paper §3 resizing, run downward), and dropping one owner bit
    hands shards ``2s`` and ``2s + 1`` to the new shard ``s``: after a
    per-shard width-true requotient the owner parity becomes the local
    top bit, so every entry of shard ``2s + 1`` lands exactly one
    half-table above shard ``2s``'s entries.  Both inputs are sorted
    streams with all of ``2s``'s quotients preceding ``2s + 1``'s
    offset quotients, so the redistribution is one sort-free two-stream
    merge + rebuild per pair — the same streaming pass schedule as
    every other structural op in this repo.  The local table geometry
    is unchanged; only the stacked leading dim halves.
    """
    if not _can_halve(cfg):
        raise ValueError(
            f"cannot halve q={cfg.q}, r={cfg.r}, n_shards={cfg.n_shards}"
        )
    new_cfg = cfg._replace(q=cfg.q - 1, r=cfg.r + 1, n_shards=cfg.n_shards // 2)
    lold, lnew = cfg.core.local_cfg, new_cfg.core.local_cfg
    # same local geometry before and after: one quotient bit moves from
    # the local table to the remainder while one owner bit moves back in
    assert (lnew.q, lnew.r) == (lold.q, lold.r)
    # width-true split: stored remainders carry the global r bits only
    win = lold._replace(r=cfg.r)
    wout = win._replace(q=lold.q - 1, r=cfg.r + 1)
    half = 1 << wout.q  # odd shards' entries take the upper half

    def one(pair):
        even = jax.tree.map(lambda x: x[0], pair)
        odd = jax.tree.map(lambda x: x[1], pair)
        qe, re_, ne = qf.extract(lold, even)
        qo, ro, no = qf.extract(lold, odd)
        qe, re_ = qf._requotient(qe, re_, win, wout)
        qo, ro = qf._requotient(qo, ro, win, wout)
        qo = jnp.where(qo == qf.INT32_MAX, qf.INT32_MAX, qo + half)
        allq, allr = qf.merge_streams(qe, re_, ne, qo, ro, no)
        new = qf.build_sorted(lnew, allq, allr, ne + no)
        return new._replace(overflow=new.overflow | even.overflow | odd.overflow)

    paired = jax.tree.map(
        lambda x: x.reshape(new_cfg.n_shards, 2, *x.shape[1:]), state
    )
    merged = jax.vmap(one)(paired)
    # the result leaves inherit the old (wider) device placement; commit
    # them onto the halved mesh so the shard_map'd step functions see a
    # consistent layout
    from jax.sharding import NamedSharding, PartitionSpec

    sharding = NamedSharding(
        _mesh(new_cfg.n_shards, new_cfg.axis), PartitionSpec(new_cfg.axis)
    )
    return new_cfg, jax.tree.map(lambda x: jax.device_put(x, sharding), merged)


def stats(cfg: ShardedQFilterConfig, state):
    return {
        "n": jnp.sum(state.n),
        "shard_counts": state.n,
        "load": jnp.sum(state.n).astype(jnp.float32) / (1 << cfg.q),
        "overflow": jnp.any(state.overflow),
        "size_bytes": cfg.n_shards * cfg.core.local_cfg.size_bytes,
    }


IMPL = register(
    FilterImpl(
        name="sharded_qf",
        paper_section="§6 (future work: multi-device AMQ, quotient-prefix sharded)",
        cfg_cls=ShardedQFilterConfig,
        make=make,
        insert=insert,
        contains=contains,
        stats=stats,
        merge=merge,
        needs_resize=needs_resize,
        grow=grow,
        resize=resize,
        needs_shrink=needs_shrink,
        shrink=shrink,
    )
)
