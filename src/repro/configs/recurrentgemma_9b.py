"""RecurrentGemma-9B / Griffin [arXiv:2402.19427]: hybrid with pattern
(rec, rec, attn) — RG-LRU recurrent blocks + local (2048-window) MQA
attention.  38 layers = 12 scanned pattern units + 2 tail rec layers.
Sub-quadratic: runs long_500k."""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        block_pattern=("rec", "rec", "attn"),
        lru_width=4096,
        attn_window=2048,
        mlp_kind="geglu",
        embed_scale=True,
        tie_embeddings=True,
    )
)
