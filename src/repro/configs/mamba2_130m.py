"""Mamba2-130M [arXiv:2405.21060]: attention-free SSD (state-space
duality) stack; the only pure-SSM arch in the pool — runs long_500k."""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=1,  # attention unused
        n_kv_heads=1,
        head_dim=1,
        d_ff=0,
        vocab_size=50280,
        attn_kind="none",
        rope="none",
        ssm_d_state=128,
        ssm_d_conv=4,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_n_groups=1,
        ssm_chunk=256,
        tie_embeddings=True,
        norm_eps=1e-5,
    )
)
