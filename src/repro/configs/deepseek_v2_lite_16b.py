"""DeepSeek-V2-Lite 16B [arXiv:2405.04434]: MLA (kv_lora 512, rope dim
64, nope 128) + fine-grained MoE: 64 routed experts top-6 plus 2 shared,
moe d_ff 1408, first layer dense (d_ff 10944).

Assignment-line note: the line says both "MoE 64e top-6" and "2
shared+160 routed"; 160 routed is the 236B DeepSeek-V2.  We follow the
*Lite* paper: 64 routed + 2 shared (recorded in DESIGN.md §5).
"""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=10944,
        vocab_size=102400,
        attn_kind="mla",
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        mlp_kind="swiglu",
        n_experts=64,
        n_shared_experts=2,
        top_k=6,
        moe_d_ff=1408,
        first_dense_layers=1,
    )
)
