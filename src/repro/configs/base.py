"""Model configuration schema + registry for the assigned architectures."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    attn_kind: str = "gqa"  # gqa | mla | none
    qk_norm: bool = False
    rope: str = "rope"  # rope | mrope | learned | none
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] = ()
    attn_window: int = 0  # 0 = global; >0 = sliding-window (local) attn
    logit_softcap: float = 0.0

    # mlp
    mlp_kind: str = "swiglu"  # swiglu | geglu | gelu

    # moe
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25

    # mla (deepseek-v2)
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # ssm (mamba2 / SSD)
    ssm_d_state: int = 0
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_n_groups: int = 1
    ssm_chunk: int = 256

    # hybrid (recurrentgemma): repeating block pattern
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    lru_width: int = 0

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0  # precomputed frame positions (stub frontend)
    frontend: str = "none"  # none | audio_stub | vision_stub

    # embeddings / norms
    embed_scale: bool = False  # gemma: embeddings * sqrt(d_model)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act_dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # training
    max_seq: int = 8192

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- analytic parameter / FLOP counts (roofline §) --------------------

    def param_count(self) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        n = v * d  # embed
        if not self.tie_embeddings:
            n += v * d
        pattern = self.block_pattern or ("attn",)

        def attn_params() -> int:
            if self.attn_kind == "mla":
                qd = self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                return (
                    d * qd
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank
                    * self.n_heads
                    * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d
                )
            qo = d * self.n_heads * self.head_dim * 2
            kv = d * self.n_kv_heads * self.head_dim * 2
            return qo + kv

        def mlp_params(width: int) -> int:
            mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
            return mult * d * width

        def layer_params(kind: str, layer_idx: int) -> int:
            if kind == "rec":
                w = self.lru_width or d
                # gate/rec/out projections + conv + RG-LRU gate matrices
                return 3 * d * w + 2 * w * w + 8 * w + mlp_params(ff)
            if kind == "ssm":
                d_in = self.ssm_expand * d
                conv_dim = d_in + 2 * self.ssm_n_groups * self.ssm_d_state
                return (
                    d
                    * (
                        2 * d_in
                        + 2 * self.ssm_n_groups * self.ssm_d_state
                        + d_in // self.ssm_head_dim
                    )
                    + conv_dim * self.ssm_d_conv
                    + d_in * d
                )
            base = attn_params()
            if self.is_moe and layer_idx >= self.first_dense_layers:
                base += (self.n_experts + self.n_shared_experts) * mlp_params(
                    self.moe_d_ff or ff
                ) + d * self.n_experts
            else:
                base += mlp_params(ff)
            return base

        if self.family == "ssm":
            kinds = ["ssm"] * self.n_layers
        elif self.block_pattern:
            kinds = [
                self.block_pattern[i % len(self.block_pattern)]
                for i in range(self.n_layers)
            ]
        else:
            kinds = ["attn"] * self.n_layers
        n += sum(layer_params(k, i) for i, k in enumerate(kinds))
        n += self.encoder_layers * (attn_params() * 2 + mlp_params(ff))
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        moe_ff = self.moe_d_ff or self.d_ff
        per_expert = mult * self.d_model * moe_ff
        moe_layers = self.n_layers - self.first_dense_layers
        inactive = moe_layers * (self.n_experts - self.top_k) * per_expert
        return full - inactive


def make_smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small width/depth,
    few experts, tiny vocab — structure preserved (pattern, attn kind,
    GQA ratio, MoE/shared experts, MLA dims scaled)."""
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 4 if not cfg.block_pattern else 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=max(
            1, min(cfg.n_kv_heads, 4 * cfg.n_kv_heads // max(cfg.n_heads, 1)) or 1
        ),
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        max_seq=128,
        param_dtype="float32",
        act_dtype="float32",
    )
    if cfg.is_moe:
        kw.update(
            n_experts=min(cfg.n_experts, 8),
            top_k=min(cfg.top_k, 2),
            moe_d_ff=64,
            n_shared_experts=min(cfg.n_shared_experts, 1),
            first_dense_layers=min(cfg.first_dense_layers, 1),
        )
    if cfg.attn_kind == "mla":
        kw.update(kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32)
    if cfg.family == "ssm":
        kw.update(ssm_d_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=16)
    if cfg.block_pattern:
        kw.update(lru_width=128, attn_window=32)
    if cfg.is_encoder_decoder:
        kw.update(encoder_layers=2, encoder_seq=16)
    if cfg.rope == "mrope":
        kw.update(mrope_sections=(4, 6, 6))  # sums to head_dim/2 = 16
    return cfg.replace(**kw)


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import config modules lazily so registry fills on first use
    from repro import configs as _c  # noqa

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from repro import configs as _c  # noqa

    return sorted(_REGISTRY)
