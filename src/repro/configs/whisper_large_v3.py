"""Whisper large-v3 [arXiv:2212.04356]: encoder-decoder ASR transformer.

The conv/mel frontend is a STUB per the assignment: input_specs provides
precomputed (B, 1500, d_model) frame embeddings for the encoder.
32 encoder + 32 decoder layers, full MHA (kv == heads), learned
positions, GELU MLP, LayerNorm.
"""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="whisper-large-v3",
        family="audio",
        n_layers=32,
        encoder_layers=32,
        encoder_seq=1500,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=51866,
        rope="learned",
        mlp_kind="gelu",
        frontend="audio_stub",
        max_seq=4096,
        norm_eps=1e-5,
    )
)
