"""Architecture registry: importing this package registers all configs."""

from .base import ModelConfig, get_config, list_configs, register, make_smoke  # noqa

from . import (  # noqa
    whisper_large_v3,
    qwen2_vl_7b,
    gemma_7b,
    qwen3_8b,
    deepseek_7b,
    starcoder2_15b,
    mamba2_130m,
    recurrentgemma_9b,
    grok_1_314b,
    deepseek_v2_lite_16b,
)

ARCHS = list_configs()
