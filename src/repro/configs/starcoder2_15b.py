"""StarCoder2-15B [arXiv:2402.19173]: GQA kv=4, RoPE, plain GELU MLP.

(The paper's canonical AMQ use case — code dedup at dataset scale —
runs through this arch's data pipeline in examples/dedup_pipeline.py.)
"""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        mlp_kind="gelu",
        norm_eps=1e-5,
    )
)
