"""Qwen2-VL-7B [arXiv:2409.12191]: VLM backbone with M-RoPE.

Vision frontend is a STUB per the assignment (text-token stream; patch
embeddings would merge into the same stream).  M-RoPE: rotary dims are
split into (temporal, height, width) sections [16, 24, 24] with three
position streams (all equal for text).
"""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-vl-7b",
        family="vlm",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152064,
        rope="mrope",
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),
        mlp_kind="swiglu",
        frontend="vision_stub",
    )
)
