"""Gemma-7B [arXiv:2403.08295]: GeGLU, head_dim 256 (attn dim 4096 !=
d_model 3072), embeddings scaled by sqrt(d_model) and tied, RMSNorm
with (1 + scale) parameterization."""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="gemma-7b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=16,
        n_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab_size=256000,
        mlp_kind="geglu",
        embed_scale=True,
        tie_embeddings=True,
    )
)
