"""Grok-1 314B [hf:xai-org/grok-1]: 64-layer MoE, 8 experts top-2,
GQA kv=8, attention logit softcap 30."""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=32768,
        vocab_size=131072,
        mlp_kind="geglu",  # grok-1 release: linear/linear_v/linear_1 (gated)
        n_experts=8,
        top_k=2,
        moe_d_ff=32768,
        logit_softcap=30.0,
        rope_theta=10_000.0,
    )
)
