"""Core library: the paper's AMQ data structures, bulk-parallel in JAX.

Quotient filter (§3), buffered quotient filter and cascade filter (§4),
plus the Bloom-filter baselines (§2) and the memory-hierarchy cost
model that stands in for the paper's SSD.

Prefer the unified functional façade in :mod:`repro.filters` for new
code: ``filters.make(name, **spec) -> (cfg, state)`` with jittable
insert/contains/delete/merge over pure pytree states.  The
``BufferedQuotientFilter``/``CascadeFilter`` dataclasses here are
deprecated host-driven shims.
"""

from . import bf_variants, bloom, cost_model, fingerprint, quotient_filter
from .buffered_qf import BufferedQuotientFilter
from .cascade_filter import CascadeFilter

__all__ = [
    "bf_variants",
    "bloom",
    "cost_model",
    "fingerprint",
    "quotient_filter",
    "BufferedQuotientFilter",
    "CascadeFilter",
]
