"""Vectorized fingerprinting for AMQ structures.

The paper hashes every key to a p-bit fingerprint f, split as
``f_q = f >> r`` (quotient) and ``f_r = f mod 2**r`` (remainder).

TPU adaptation: the VPU is 32-bit-lane hardware and jax defaults to
32-bit integers, so the conceptual 64-bit hash is carried as two 32-bit
words (hi, lo) produced by independent murmur3 fmix32 streams.  The
fingerprint is the **top p = q + r bits** of (hi:lo); bit extraction is
done with static python-int shifts so quotient/remainder stay
*consistent across any (q, r) split of the same p* — which is what
makes the paper's resize (borrow a bit from the remainder) and merge
(re-quotient to a larger table) operations exact.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "fmix32",
    "hash2",
    "fingerprint",
    "extract_bits",
    "fold_bytes",
]

_GOLDEN = jnp.uint32(0x9E3779B9)


def fmix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer — a full-avalanche mixer (vectorized)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def hash2(keys: jnp.ndarray, seed: int = 0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Two independent 32-bit hash words (hi, lo) per key = one 64-bit hash."""
    k = keys.astype(jnp.uint32)
    s = jnp.uint32(seed)
    hi = fmix32(k ^ fmix32(s * jnp.uint32(2) + jnp.uint32(1)))
    lo = fmix32((k + _GOLDEN) ^ fmix32(s * jnp.uint32(2) + jnp.uint32(2)))
    return hi, lo


def _mask(width: int) -> jnp.ndarray:
    return jnp.uint32(0xFFFFFFFF if width >= 32 else (1 << width) - 1)


def extract_bits(hi: jnp.ndarray, lo: jnp.ndarray, start: int, width: int):
    """Bits [start, start+width) of the 64-bit word (hi:lo), MSB-first.

    All shifts are static python ints (no dynamic shift hazards).
    width <= 32.
    """
    if not (0 < width <= 32 and 0 <= start and start + width <= 64):
        raise ValueError(f"bad bit slice start={start} width={width}")
    end = start + width
    if end <= 32:
        return (hi >> jnp.uint32(32 - end)) & _mask(width)
    if start >= 32:
        return (lo >> jnp.uint32(64 - end)) & _mask(width)
    hi_bits = 32 - start
    lo_bits = end - 32
    hipart = hi & _mask(hi_bits)
    return ((hipart << jnp.uint32(lo_bits)) | (lo >> jnp.uint32(32 - lo_bits))) & _mask(
        width
    )


def fingerprint(keys: jnp.ndarray, q: int, r: int, seed: int = 0):
    """keys -> (quotient int32 (B,), remainder uint32 (B,)).

    quotient = top q bits of the 64-bit hash, remainder = next r bits.
    """
    if not (1 <= q <= 30):
        raise ValueError(f"q must be in [1, 30], got {q}")
    if not (1 <= r <= 32):
        raise ValueError(f"r must be in [1, 32], got {r}")
    hi, lo = hash2(keys, seed)
    fq = extract_bits(hi, lo, 0, q).astype(jnp.int32)
    fr = extract_bits(hi, lo, q, r)
    return fq, fr


def fold_bytes(data: bytes, seed: int = 0) -> int:
    """Host-side FNV-1a fold of arbitrary bytes to a 32-bit key.

    Used by the data pipeline to digest documents before the on-device
    fingerprint path (mirrors the paper's "512-bit hash per item" setup:
    upstream produces a wide digest, the filter consumes what it needs).
    """
    h = (0x811C9DC5 ^ seed) & 0xFFFFFFFF
    for b in data:
        h ^= b
        h = (h * 0x01000193) & 0xFFFFFFFF
    return h
