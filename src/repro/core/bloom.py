"""Bloom filter baseline (paper §2), vectorized for the JAX port.

Representation note: the canonical BF is a packed bit array.  XLA has
no scatter-OR, so the device representation is one byte per bit with
``.at[idx].max(1)`` scatter (duplicate-safe); *space accounting* (used
by every benchmark and by the FP-rate math) is in bits, matching the
paper.  The counting Bloom filter uses the same array as 8-bit
counters (the paper's 4-bit counters would saturate identically for
our workloads; space is accounted at 4 bits per counter, matching [3]).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .fingerprint import fmix32

__all__ = [
    "BloomConfig",
    "optimal_k",
    "empty",
    "insert",
    "lookup",
    "bit_indices",
    "counting_delete",
]


class BloomConfig(NamedTuple):
    m_bits: int
    k: int
    seed: int = 0
    counting: bool = False

    @property
    def size_bytes(self) -> int:
        # modeled: 1 bit per cell (plain) / 4 bits per cell (counting)
        return (self.m_bits * (4 if self.counting else 1) + 7) // 8


def optimal_k(bits_per_element: float) -> int:
    """k = (m/n) ln 2, the paper's optimal hash count."""
    import math

    return max(1, round(bits_per_element * math.log(2)))


def empty(cfg: BloomConfig) -> jnp.ndarray:
    return jnp.zeros((cfg.m_bits,), jnp.uint8)


def bit_indices(cfg: BloomConfig, keys: jnp.ndarray) -> jnp.ndarray:
    """(B, k) bit positions via double hashing h1 + i*h2 (Kirsch-Mitzenmacher)."""
    k32 = keys.astype(jnp.uint32)
    h1 = fmix32(k32 ^ jnp.uint32(cfg.seed * 2 + 0x7F4A7C15))
    h2 = fmix32(k32 ^ jnp.uint32(cfg.seed * 2 + 0x94D049BB)) | jnp.uint32(1)
    i = jnp.arange(cfg.k, dtype=jnp.uint32)
    idx = (h1[:, None] + i[None, :] * h2[:, None]) % jnp.uint32(cfg.m_bits)
    return idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnums=0)
def insert(cfg: BloomConfig, bits: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    idx = bit_indices(cfg, keys).reshape(-1)
    if cfg.counting:
        return bits.at[idx].add(jnp.uint8(1))
    return bits.at[idx].max(jnp.uint8(1))


@functools.partial(jax.jit, static_argnums=0)
def counting_delete(cfg: BloomConfig, bits: jnp.ndarray, keys: jnp.ndarray):
    if not cfg.counting:
        raise ValueError("delete requires a counting Bloom filter")
    idx = bit_indices(cfg, keys).reshape(-1)
    return bits.at[idx].add(jnp.uint8(255))  # wrapping -1


@functools.partial(jax.jit, static_argnums=0)
def lookup(cfg: BloomConfig, bits: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    """MAY-CONTAIN: AND of the k probed cells."""
    idx = bit_indices(cfg, keys)
    return jnp.all(bits[idx] > 0, axis=1)


@functools.partial(jax.jit, static_argnums=0)
def probes_until_reject(cfg: BloomConfig, bits: jnp.ndarray, keys: jnp.ndarray):
    """Number of cells a short-circuiting lookup reads per key.

    The paper's I/O analysis hinges on this: an absent key reads ~2
    cells in expectation, a present key reads all k.  Used by the
    EBF/BBF page-accounting.
    """
    idx = bit_indices(cfg, keys)
    vals = bits[idx] > 0
    # first zero position (k if none)
    anyz = jnp.any(~vals, axis=1)
    first0 = jnp.argmax(~vals, axis=1)
    return jnp.where(anyz, first0 + 1, cfg.k), idx
