"""Cascade filter (paper §4) — legacy host-driven API.

.. deprecated::
    New code should use the functional implementation behind the
    ``repro.filters`` façade (``repro.filters.make("cascade", ...)``):
    pytree state, ``lax.switch`` merge-downs on device counts, device
    I/O counters, one ``lax.scan`` per ingest loop.  This dataclass
    stays for host-driven callers that want lazily allocated levels or
    the deamortized I/O accounting below.

COLA-style hierarchy: a small RAM quotient filter Q0 plus on-"disk"
QFs Q_1..Q_l whose capacities grow geometrically with the fanout b.
When Q0 reaches its max load, the smallest i is found such that all
elements of Q0..Q_i fit in level i, and Q0..Q_i are k-way-merged into a
fresh Q_i (one sequential streaming pass); smaller levels empty.

Amortized insert cost: O(log_b(n/M) / B) block writes — each element is
rewritten once per level it passes through.  Lookup: one random page
read per non-empty level (short-circuited top-down).

``deamortize=True`` spreads each merge's I/O accounting over subsequent
insert batches — modeling the background-merge "cleaner" the paper
sketches in §6 (compute is applied immediately; only the modeled I/O
schedule is smoothed).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax.numpy as jnp

from . import quotient_filter as qf
from .cost_model import IOLog


@dataclass
class CascadeFilter:
    ram_q: int  # log2 buckets of Q0
    p: int  # fingerprint bits (q + r at every level)
    fanout: int = 2
    max_levels: int = 24
    seed: int = 0
    max_load: float = 0.75
    deamortize: bool = False
    io: IOLog = field(default_factory=IOLog)

    def __post_init__(self):
        if self.fanout < 2 or (self.fanout & (self.fanout - 1)):
            raise ValueError("fanout must be a power of two >= 2")
        self.lb = int(math.log2(self.fanout))
        self.q0_cfg = self._cfg(self.ram_q)
        self.q0 = qf.empty(self.q0_cfg)
        # levels created lazily; level i has q = ram_q + (i+1)*log2(b)
        self.levels: list[tuple[qf.QFConfig, qf.QFState]] = []
        self._pending_io = 0.0  # deamortized bytes not yet charged

    def _cfg(self, q: int) -> qf.QFConfig:
        return qf.QFConfig(
            q=q,
            r=self.p - q,
            slack=max(1024, (1 << q) // 64),
            seed=self.seed,
            max_load=self.max_load,
        )

    def _level_cfg(self, i: int) -> qf.QFConfig:
        return self._cfg(self.ram_q + (i + 1) * self.lb)

    @property
    def count(self) -> int:
        return int(self.q0.n) + sum(int(s.n) for _, s in self.levels)

    @property
    def size_bytes(self) -> int:
        return self.q0_cfg.size_bytes + sum(c.size_bytes for c, _ in self.levels)

    # -- inserts ------------------------------------------------------------

    def insert(self, keys: jnp.ndarray) -> None:
        self.q0 = qf.insert(self.q0_cfg, self.q0, keys)
        if float(qf.load(self.q0_cfg, self.q0)) >= self.max_load:
            self._merge_down()
        self._charge_pending(len(keys))

    def _merge_down(self) -> None:
        """Find the smallest level that fits Q0..Q_i and collapse into it."""
        n = int(self.q0.n)
        target = None
        for i in range(self.max_levels):
            cfg_i = self._level_cfg(i)
            n_i = n + sum(
                int(s.n) for _, s in self.levels[: i + 1] if s is not None
            )
            if n_i <= cfg_i.capacity:
                target = i
                n = n_i
                break
        if target is None:
            raise RuntimeError("cascade filter exhausted max_levels")
        while len(self.levels) <= target:
            c = self._level_cfg(len(self.levels))
            self.levels.append((c, qf.empty(c)))
        parts = [(self.q0_cfg, self.q0)] + [
            (c, s) for c, s in self.levels[: target + 1] if int(s.n) > 0
        ]
        cfg_t = self._level_cfg(target)
        merged = qf.multi_merge(cfg_t, parts)
        # I/O: stream every participating structure in, the target out
        read_bytes = sum(c.size_bytes for c, s in parts[1:])  # Q0 is RAM
        write_bytes = cfg_t.size_bytes
        if self.deamortize:
            self._pending_io += read_bytes + write_bytes
        else:
            self.io.seq_read_bytes += read_bytes
            self.io.seq_write_bytes += write_bytes
        self.io.merges += 1
        self.io.flushes += 1
        self.levels[target] = (cfg_t, merged)
        for j in range(target):
            c = self._level_cfg(j)
            self.levels[j] = (c, qf.empty(c))
        self.q0 = qf.empty(self.q0_cfg)

    def _charge_pending(self, batch: int) -> None:
        """Deamortized mode: charge buffered merge I/O smoothly."""
        if not self.deamortize or self._pending_io <= 0:
            return
        # charge proportionally to Q0 fill progress (one Q0 fill drains
        # at most one outstanding merge — the COLA deamortization rate)
        rate = self._pending_io * batch / max(1, self.q0_cfg.capacity)
        charge = min(self._pending_io, rate)
        self.io.seq_write_bytes += int(charge)
        self._pending_io -= charge

    # -- lookups ------------------------------------------------------------

    def lookup(self, keys: jnp.ndarray) -> jnp.ndarray:
        hit = qf.contains(self.q0_cfg, self.q0, keys)
        for cfg, state in self.levels:
            if int(state.n) == 0:
                continue
            pending = ~hit
            n_pending = int(jnp.sum(pending))
            if n_pending == 0:
                break
            lvl_hit = qf.contains(cfg, state, keys)
            # short-circuit: only still-unresolved queries touch this level
            self.io.rand_page_reads += n_pending
            hit = hit | (pending & lvl_hit)
        return hit

    def n_nonempty_levels(self) -> int:
        return sum(1 for _, s in self.levels if int(s.n) > 0)
