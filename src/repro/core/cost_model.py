"""Memory-hierarchy cost models.

The paper's evaluation is throughput on a concrete hierarchy
(3 GB RAM + Intel X25-M SSD).  This container has neither an SSD nor a
TPU, so on-"disk" structures account their exact access schedule
(random page reads/writes, sequential bytes) into an :class:`IOLog`,
and a profile converts the log into modeled seconds.

Two calibrations ship:

* :data:`PAPER_SSD` — the paper's own measured constants (§1/Table 1
  context: 3,910 random 1-byte writes/s, 3,200 random reads/s,
  261 MB/s sequential read, 109 MB/s sequential write, 4 KiB pages).
  Used by the Table-1b reproduction benchmarks.
* :data:`TPU_V5E` — the target hardware for the JAX port: HBM streaming
  vs gather-limited access plus ICI hops for the sharded filter.
  Used by the beyond-paper analysis in EXPERIMENTS.md.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class HierarchyProfile:
    name: str
    rand_read_ops_per_s: float  # random page reads / second
    rand_write_ops_per_s: float  # random page writes / second
    seq_read_bytes_per_s: float
    seq_write_bytes_per_s: float
    page_bytes: int
    ram_bytes: int  # "fast tier" budget


PAPER_SSD = HierarchyProfile(
    name="intel-x25m-paper",
    rand_read_ops_per_s=3_200.0,
    rand_write_ops_per_s=3_910.0,
    seq_read_bytes_per_s=261e6,
    seq_write_bytes_per_s=109e6,
    page_bytes=4096,
    ram_bytes=2 << 30,  # 2 GB filter budget in the paper's experiments
)

# TPU v5e: HBM streams at 819 GB/s; "random" page access modeled as one
# 512 B gather transaction at an effective ~10x bandwidth derate
# (gather-limited HBM); ICI ~50 GB/s/link is tracked separately by the
# roofline harness, not here.
TPU_V5E = HierarchyProfile(
    name="tpu-v5e-hbm",
    rand_read_ops_per_s=819e9 / 512 / 10,
    rand_write_ops_per_s=819e9 / 512 / 10,
    seq_read_bytes_per_s=819e9,
    seq_write_bytes_per_s=819e9,
    page_bytes=512,
    ram_bytes=128 << 20,  # VMEM
)


@dataclass
class IOLog:
    """Exact access schedule of an on-"disk" structure."""

    rand_page_reads: int = 0
    rand_page_writes: int = 0
    seq_read_bytes: int = 0
    seq_write_bytes: int = 0
    # informational
    flushes: int = 0
    merges: int = 0
    notes: dict = field(default_factory=dict)

    def clear(self) -> None:
        self.rand_page_reads = 0
        self.rand_page_writes = 0
        self.seq_read_bytes = 0
        self.seq_write_bytes = 0
        self.flushes = 0
        self.merges = 0

    def snapshot(self) -> "IOLog":
        return IOLog(
            rand_page_reads=self.rand_page_reads,
            rand_page_writes=self.rand_page_writes,
            seq_read_bytes=self.seq_read_bytes,
            seq_write_bytes=self.seq_write_bytes,
            flushes=self.flushes,
            merges=self.merges,
        )

    def delta(self, since: "IOLog") -> "IOLog":
        return IOLog(
            rand_page_reads=self.rand_page_reads - since.rand_page_reads,
            rand_page_writes=self.rand_page_writes - since.rand_page_writes,
            seq_read_bytes=self.seq_read_bytes - since.seq_read_bytes,
            seq_write_bytes=self.seq_write_bytes - since.seq_write_bytes,
            flushes=self.flushes - since.flushes,
            merges=self.merges - since.merges,
        )


def modeled_seconds(log: IOLog, profile: HierarchyProfile) -> float:
    """Convert an access schedule into modeled I/O seconds."""
    return (
        log.rand_page_reads / profile.rand_read_ops_per_s
        + log.rand_page_writes / profile.rand_write_ops_per_s
        + log.seq_read_bytes / profile.seq_read_bytes_per_s
        + log.seq_write_bytes / profile.seq_write_bytes_per_s
    )


def modeled_throughput(n_ops: int, log: IOLog, profile: HierarchyProfile) -> float:
    """ops/second implied by the schedule (inf if no I/O was needed)."""
    secs = modeled_seconds(log, profile)
    return float("inf") if secs == 0 else n_ops / secs


# ---------------------------------------------------------------------------
# Frozen-tier geometry: binary-fuse vs quotient-filter cold levels
# ---------------------------------------------------------------------------
#
# A cascade level below Q0 is write-once between merge-downs, which is
# the contract the Graf & Lemire xor / binary-fuse filters exploit: an
# immutable table of ~1.125-1.4x n fingerprint cells (3-wise segmented
# layout) answered by exactly FUSE_PROBE_READS independent reads.  The
# helpers below are the single source of truth for that geometry —
# ``core.fuse_filter`` sizes its tables with them, the cascade's
# ``frozen_below`` mode derives per-level fuse configs from them, and
# ``benchmarks/bench_xor_fuse.py`` + the cost-model unit test validate
# the predictions against measured ``IOCounters``.

FUSE_ARITY = 3
#: independent table reads per probe (the xor-filter access schedule);
#: the three touched segments are consecutive, so on a page device they
#: often coalesce, but the *schedule* is three independent gathers.
FUSE_PROBE_READS = 3
#: QF cluster lookups touch one contiguous region = one page.
QF_PROBE_READS = 1


def fuse_segment_length(capacity: int) -> int:
    """Binary-fuse segment length (power of two) for a design capacity.

    Follows the Graf & Lemire sizing shape: segments grow slowly with n
    (``~ n ** (1/log 3.33)``), clamped to [16, 4096].
    """
    if capacity <= 1:
        return 16
    raw = int(math.floor(math.log(capacity) / math.log(3.33) + 2.25))
    return 1 << max(4, min(12, raw))


def fuse_size_factor(capacity: int) -> float:
    """Table-slots-per-key expansion at which 3-wise peeling succeeds whp.

    Large sets approach the asymptotic 1.125; small sets need
    proportionally more head-room (Graf & Lemire's small-n correction),
    plus a safety margin since construction retries are host-level.
    """
    n = max(capacity, 8)
    return max(1.125, 0.875 + 0.30 * math.log(1e6) / math.log(n))


def fuse_segment_count(capacity: int, segment_length: int | None = None) -> int:
    L = segment_length or fuse_segment_length(capacity)
    need = fuse_size_factor(capacity) * max(capacity, 1)
    return max(1, math.ceil(need / L) - 2)


def fuse_slots(capacity: int, segment_length: int | None = None) -> int:
    """Total fingerprint cells of a binary-fuse table sized for ``capacity``."""
    L = segment_length or fuse_segment_length(capacity)
    return (fuse_segment_count(capacity, L) + 2) * L


def fuse_bits_per_key(
    capacity: int, fp_bits: int, segment_length: int | None = None
) -> float:
    """Modeled probe-structure bits per key of a frozen (binary-fuse) level."""
    return fuse_slots(capacity, segment_length) * fp_bits / max(capacity, 1)


def qf_bits_per_key(q: int, r: int, slack: int, max_load: float = 0.75) -> float:
    """Modeled bits per key of a QF level at its design capacity.

    (r + 3 metadata bits) per slot over m + slack slots, against the
    ``max_load * m`` keys the level is sized to hold.
    """
    m = 1 << q
    return (m + slack) * (r + 3) / (m * max_load)


def fuse_fp_bits_for(r: int, max_load: float = 0.75) -> int:
    """Stored fingerprint width matching a QF level's fp rate.

    A QF at load ``a`` false-positives at ``~a * 2^-r``; a fuse table at
    ``2^-f``.  ``f = r + ceil(log2(1/a))`` makes the frozen level at
    least as selective.  Clamped to the uint32 cell layout.
    """
    extra = max(0, math.ceil(-math.log2(max_load)))
    return max(4, min(28, r + extra))


def frozen_level_saving(
    q: int,
    r: int,
    slack: int,
    max_load: float = 0.75,
    fp_bits: int | None = None,
) -> float:
    """Fractional probe-structure space saved by demoting one QF level
    to binary-fuse form at the same fp-rate target (positive = smaller)."""
    capacity = int((1 << q) * max_load)
    f = fp_bits if fp_bits is not None else fuse_fp_bits_for(r, max_load)
    qf_bits = qf_bits_per_key(q, r, slack, max_load)
    fz_bits = fuse_bits_per_key(capacity, f)
    return 1.0 - fz_bits / qf_bits


def recommend_frozen_below(
    ram_q: int,
    p: int,
    fanout: int = 2,
    levels: int = 4,
    max_load: float = 0.75,
    min_saving: float = 0.10,
) -> int | None:
    """Smallest cascade depth k at which demoting levels >= k to
    binary-fuse form saves at least ``min_saving`` of their
    probe-structure bits — the family auto-pick hook.

    Returns None when no depth clears the bar (e.g. tiny levels where
    segment-granularity padding eats the win).
    """
    lb = int(math.log2(fanout))
    for i in range(levels):
        q = ram_q + (i + 1) * lb
        r = p - q
        if r < 2:
            continue
        slack = max(1024, (1 << q) // 64)
        if frozen_level_saving(q, r, slack, max_load) >= min_saving:
            return i
    return None


def cascade_probe_reads(
    n_queries: int, nonempty: list, frozen: list | None = None
) -> int:
    """Predicted ``rand_page_reads`` for probing ``n_queries`` all-miss
    keys through a cascade: every query stays pending at every level, so
    each non-empty level charges one cluster read (QF) or
    ``FUSE_PROBE_READS`` gathers (frozen) per query.  Validated against
    measured ``IOCounters`` in ``tests/test_xor_fuse.py``.
    """
    frozen = frozen or [False] * len(nonempty)
    reads = 0
    for ne, fz in zip(nonempty, frozen):
        if ne:
            reads += n_queries * (FUSE_PROBE_READS if fz else QF_PROBE_READS)
    return reads
