"""Memory-hierarchy cost models.

The paper's evaluation is throughput on a concrete hierarchy
(3 GB RAM + Intel X25-M SSD).  This container has neither an SSD nor a
TPU, so on-"disk" structures account their exact access schedule
(random page reads/writes, sequential bytes) into an :class:`IOLog`,
and a profile converts the log into modeled seconds.

Two calibrations ship:

* :data:`PAPER_SSD` — the paper's own measured constants (§1/Table 1
  context: 3,910 random 1-byte writes/s, 3,200 random reads/s,
  261 MB/s sequential read, 109 MB/s sequential write, 4 KiB pages).
  Used by the Table-1b reproduction benchmarks.
* :data:`TPU_V5E` — the target hardware for the JAX port: HBM streaming
  vs gather-limited access plus ICI hops for the sharded filter.
  Used by the beyond-paper analysis in EXPERIMENTS.md.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class HierarchyProfile:
    name: str
    rand_read_ops_per_s: float  # random page reads / second
    rand_write_ops_per_s: float  # random page writes / second
    seq_read_bytes_per_s: float
    seq_write_bytes_per_s: float
    page_bytes: int
    ram_bytes: int  # "fast tier" budget


PAPER_SSD = HierarchyProfile(
    name="intel-x25m-paper",
    rand_read_ops_per_s=3_200.0,
    rand_write_ops_per_s=3_910.0,
    seq_read_bytes_per_s=261e6,
    seq_write_bytes_per_s=109e6,
    page_bytes=4096,
    ram_bytes=2 << 30,  # 2 GB filter budget in the paper's experiments
)

# TPU v5e: HBM streams at 819 GB/s; "random" page access modeled as one
# 512 B gather transaction at an effective ~10x bandwidth derate
# (gather-limited HBM); ICI ~50 GB/s/link is tracked separately by the
# roofline harness, not here.
TPU_V5E = HierarchyProfile(
    name="tpu-v5e-hbm",
    rand_read_ops_per_s=819e9 / 512 / 10,
    rand_write_ops_per_s=819e9 / 512 / 10,
    seq_read_bytes_per_s=819e9,
    seq_write_bytes_per_s=819e9,
    page_bytes=512,
    ram_bytes=128 << 20,  # VMEM
)


@dataclass
class IOLog:
    """Exact access schedule of an on-"disk" structure."""

    rand_page_reads: int = 0
    rand_page_writes: int = 0
    seq_read_bytes: int = 0
    seq_write_bytes: int = 0
    # informational
    flushes: int = 0
    merges: int = 0
    notes: dict = field(default_factory=dict)

    def clear(self) -> None:
        self.rand_page_reads = 0
        self.rand_page_writes = 0
        self.seq_read_bytes = 0
        self.seq_write_bytes = 0
        self.flushes = 0
        self.merges = 0

    def snapshot(self) -> "IOLog":
        return IOLog(
            rand_page_reads=self.rand_page_reads,
            rand_page_writes=self.rand_page_writes,
            seq_read_bytes=self.seq_read_bytes,
            seq_write_bytes=self.seq_write_bytes,
            flushes=self.flushes,
            merges=self.merges,
        )

    def delta(self, since: "IOLog") -> "IOLog":
        return IOLog(
            rand_page_reads=self.rand_page_reads - since.rand_page_reads,
            rand_page_writes=self.rand_page_writes - since.rand_page_writes,
            seq_read_bytes=self.seq_read_bytes - since.seq_read_bytes,
            seq_write_bytes=self.seq_write_bytes - since.seq_write_bytes,
            flushes=self.flushes - since.flushes,
            merges=self.merges - since.merges,
        )


def modeled_seconds(log: IOLog, profile: HierarchyProfile) -> float:
    """Convert an access schedule into modeled I/O seconds."""
    return (
        log.rand_page_reads / profile.rand_read_ops_per_s
        + log.rand_page_writes / profile.rand_write_ops_per_s
        + log.seq_read_bytes / profile.seq_read_bytes_per_s
        + log.seq_write_bytes / profile.seq_write_bytes_per_s
    )


def modeled_throughput(n_ops: int, log: IOLog, profile: HierarchyProfile) -> float:
    """ops/second implied by the schedule (inf if no I/O was needed)."""
    secs = modeled_seconds(log, profile)
    return float("inf") if secs == 0 else n_ops / secs
