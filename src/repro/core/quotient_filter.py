"""Bulk-parallel quotient filter (the paper's core contribution, §3).

A QF stores p-bit fingerprints, p = q + r, in ``m = 2**q`` buckets using
quotienting [Knuth; Cleary'84]: the quotient f_q picks the bucket, the
r-bit remainder f_r is stored, and three metadata bit-planes
(is_occupied / is_continuation / is_shifted) make the linear-probed
table exactly decodable.

TPU adaptation (see DESIGN.md §2).  The paper's item-at-a-time shifted
insert is a data-dependent scalar loop — hostile to the TPU execution
model.  We exploit the paper's own observation that a QF *is* a sorted
multiset of fingerprints:

* ``build_sorted``: for sorted quotients ``qs[i]`` the linear-probe
  position obeys ``pos[i] = max(pos[i-1] + 1, qs[i])``, which
  closed-forms to ``pos[i] = i + cummax(qs[i] - i)`` — an associative
  scan.  Metadata bits follow elementwise and everything is scattered in
  one pass.  O(n) work, fully parallel.
* ``extract``: inverse decode via rank/select prefix sums — again O(m)
  parallel.  ``build(extract(s)) == s`` exactly.
* inserts/deletes/merges/resizes are all expressed through these two
  bulk ops, i.e. *every* write is a sequential streaming pass — the
  paper's "cache your hash" locality argument taken to its bulk-
  synchronous limit.
* lookups: the paper's cluster walk becomes a fixed-width windowed
  decode (``lookup``) — one contiguous W-slot window per query, the
  TPU analogue of "one cluster = one SSD page".  An exact
  binary-search path over the decoded fingerprints (``lookup_exact``)
  serves as oracle and overflow fallback.

Layout change vs paper: the three metadata bits are stored as separate
bit-planes rather than interleaved 3-bit fields (identical space,
vectorizes decode), and the table does not wrap around — a small slack
region absorbs the final cluster (the paper's whp cluster-length bound,
§3 Fact, sizes it).  ``state.overflow`` flags the (never observed in
tests) violation.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .fingerprint import fingerprint

INT32_MAX = jnp.int32(2**31 - 1)
UINT32_MAX = jnp.uint32(0xFFFFFFFF)


class QFConfig(NamedTuple):
    """Static quotient-filter configuration (hashable; jit-static)."""

    q: int  # log2 number of buckets
    r: int  # remainder bits; false-positive rate ~= load * 2**-r
    slack: int = 1024  # extra slots past 2**q absorbing the last cluster
    seed: int = 0
    max_load: float = 0.75  # paper's recommended operating point

    @property
    def m(self) -> int:
        return 1 << self.q

    @property
    def total_slots(self) -> int:
        return self.m + self.slack

    @property
    def capacity(self) -> int:
        return int(self.m * self.max_load)

    @property
    def bits_per_slot(self) -> int:
        return self.r + 3

    @property
    def size_bytes(self) -> int:
        """Modeled size of the packed structure (r+3 bits per slot)."""
        return (self.total_slots * self.bits_per_slot + 7) // 8


class QFState(NamedTuple):
    """Device state. Planes have length cfg.total_slots."""

    rem: jnp.ndarray  # uint32 remainders
    occ: jnp.ndarray  # bool  is_occupied   (indexed by bucket)
    shf: jnp.ndarray  # bool  is_shifted    (indexed by slot)
    con: jnp.ndarray  # bool  is_continuation (indexed by slot)
    n: jnp.ndarray  # int32 scalar, number of stored fingerprints
    overflow: jnp.ndarray  # bool scalar, slack exhausted (should stay False)


def empty(cfg: QFConfig) -> QFState:
    t = cfg.total_slots
    return QFState(
        rem=jnp.zeros((t,), jnp.uint32),
        occ=jnp.zeros((t,), jnp.bool_),
        shf=jnp.zeros((t,), jnp.bool_),
        con=jnp.zeros((t,), jnp.bool_),
        n=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.bool_),
    )


def load(cfg: QFConfig, state: QFState) -> jnp.ndarray:
    """Load factor alpha = n / m."""
    return state.n.astype(jnp.float32) / cfg.m


# ---------------------------------------------------------------------------
# Fingerprints
# ---------------------------------------------------------------------------


def fingerprints(cfg: QFConfig, keys: jnp.ndarray):
    """Hash keys to (quotient, remainder) for this filter."""
    return fingerprint(keys, cfg.q, cfg.r, cfg.seed)


def _pad_sort(fq: jnp.ndarray, fr: jnp.ndarray, valid: jnp.ndarray):
    """Sort (fq, fr) lexicographically, pushing invalid entries to the end."""
    fq = jnp.where(valid, fq, INT32_MAX)
    fr = jnp.where(valid, fr, UINT32_MAX)
    fq, fr = jax.lax.sort((fq, fr), num_keys=2)
    return fq, fr


# ---------------------------------------------------------------------------
# Bulk build: sorted fingerprints -> slot planes
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=0)
def build_sorted(cfg: QFConfig, fq: jnp.ndarray, fr: jnp.ndarray, n) -> QFState:
    """Build a QF from lexicographically sorted (fq, fr), first ``n`` valid.

    Padding entries must sort after all valid ones (fq == INT32_MAX).
    """
    t = cfg.total_slots
    nn = jnp.asarray(n, jnp.int32)
    idx = jnp.arange(fq.shape[0], dtype=jnp.int32)
    valid = idx < nn

    # Linear-probe positions: pos[i] = max(pos[i-1] + 1, fq[i])
    #                                = i + cummax(fq[i] - i)          (scan)
    # The padding sentinel must stay out of the subtraction: -INT32_MAX - idx
    # wraps for idx >= 2, so the difference is formed for valid rows only.
    pos = idx + jax.lax.cummax(jnp.where(valid, fq - idx, -INT32_MAX))
    overflow = jnp.any(valid & (pos >= t))
    spos = jnp.where(valid, pos, INT32_MAX)  # scatter-drop for padding

    con_bits = valid & (idx > 0) & (fq == jnp.roll(fq, 1))
    shf_bits = valid & (pos != fq)

    rem = jnp.zeros((t,), jnp.uint32).at[spos].set(fr, mode="drop")
    shf = jnp.zeros((t,), jnp.bool_).at[spos].set(shf_bits, mode="drop")
    con = jnp.zeros((t,), jnp.bool_).at[spos].set(con_bits, mode="drop")
    occ = (
        jnp.zeros((t,), jnp.bool_)
        .at[jnp.where(valid, fq, INT32_MAX)]
        .set(True, mode="drop")
    )
    return QFState(rem=rem, occ=occ, shf=shf, con=con, n=nn, overflow=overflow)


@functools.partial(jax.jit, static_argnums=0)
def extract(cfg: QFConfig, state: QFState):
    """Decode the filter back to sorted fingerprints.

    Returns (fq, fr, n): padded (total_slots,) arrays whose first n
    entries are the sorted fingerprint multiset (padding = sentinels).
    Pure rank/select prefix arithmetic — a single sequential pass.
    """
    t = cfg.total_slots
    nonempty = state.occ | state.shf  # continuation implies shifted
    run_start = nonempty & ~state.con
    # run_id: 1-indexed run ordinal for every slot in a run
    run_id = jnp.cumsum(run_start.astype(jnp.int32))
    # bucket of the j-th run = index of the j-th set is_occupied bit
    occ_cum = jnp.cumsum(state.occ.astype(jnp.int32))
    # searchsorted(occ_cum, j, 'left') == first index with occ_cum >= j
    bucket_of_run = jnp.searchsorted(occ_cum, run_id, side="left").astype(jnp.int32)
    fq_slot = jnp.where(nonempty, bucket_of_run, INT32_MAX)
    fr_slot = jnp.where(nonempty, state.rem, UINT32_MAX)
    # compact: scatter valid entries to their rank
    dest = jnp.cumsum(nonempty.astype(jnp.int32)) - 1
    dest = jnp.where(nonempty, dest, INT32_MAX)
    fq_out = jnp.full((t,), INT32_MAX, jnp.int32).at[dest].set(fq_slot, mode="drop")
    fr_out = jnp.full((t,), UINT32_MAX, jnp.uint32).at[dest].set(fr_slot, mode="drop")
    return fq_out, fr_out, state.n


# ---------------------------------------------------------------------------
# Lookup
# ---------------------------------------------------------------------------


def _range_bsearch(rs, lo, hi, v, right: bool):
    """Vectorized binary search of v in rs[lo:hi] (per-query ranges)."""
    import math

    iters = max(1, math.ceil(math.log2(max(2, rs.shape[0]))) + 1)

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) >> 1
        x = rs[jnp.clip(mid, 0, rs.shape[0] - 1)]
        go_right = (x < v) | ((x == v) & right)
        active = lo < hi
        lo2 = jnp.where(active & go_right, mid + 1, lo)
        hi2 = jnp.where(active & ~go_right, mid, hi)
        return lo2, hi2

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo


def lex_searchsorted(qs, rs, fq, fr, side: str = "left"):
    """Rank of (fq, fr) in the lexicographically sorted (qs, rs)."""
    lo = jnp.searchsorted(qs, fq, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(qs, fq, side="right").astype(jnp.int32)
    return _range_bsearch(rs, lo, hi, fr, right=(side == "right"))


@functools.partial(jax.jit, static_argnums=0)
def lookup_exact(cfg: QFConfig, state: QFState, fq: jnp.ndarray, fr: jnp.ndarray):
    """Oracle lookup: decode + binary search. O(m) decode per batch."""
    qs, rs, _ = extract(cfg, state)
    lo = lex_searchsorted(qs, rs, fq, fr, "left")
    qh = qs[jnp.clip(lo, 0, qs.shape[0] - 1)]
    rh = rs[jnp.clip(lo, 0, rs.shape[0] - 1)]
    return (qh == fq) & (rh == fr)


def _window_decode(cfg: QFConfig, state: QFState, fq, fr, W: int):
    """One windowed-decode pass. Returns (present, overflow_flag)."""
    B = fq.shape[0]
    t = cfg.total_slots
    wtot = 2 * W
    js = jnp.arange(wtot, dtype=jnp.int32)
    base = fq - W
    idx = base[:, None] + js[None, :]
    valid = (idx >= 0) & (idx < t)
    idxc = jnp.clip(idx, 0, t - 1)

    occ = jnp.where(valid, state.occ[idxc], False)
    shf = jnp.where(valid, state.shf[idxc], False)
    con = jnp.where(valid, state.con[idxc], False)
    rem = jnp.where(valid, state.rem[idxc], jnp.uint32(0))
    nonempty = occ | shf

    occ_q = occ[:, W]  # is_occupied(A[f_q])

    # cluster/anchor start b: largest j <= W with !is_shifted
    cand = jnp.where((~shf) & (js <= W)[None, :], js[None, :], -1)
    b = jnp.max(cand, axis=1)
    ovf_left = b < 0

    # R = #occupied buckets in [b, fq]
    sel = occ & (js[None, :] >= b[:, None]) & (js <= W)[None, :]
    R = jnp.sum(sel, axis=1, dtype=jnp.int32)

    run_start = nonempty & ~con
    cum = jnp.cumsum(run_start.astype(jnp.int32), axis=1)
    cum_before = jnp.where(
        b > 0, jnp.take_along_axis(cum, jnp.maximum(b - 1, 0)[:, None], axis=1)[:, 0], 0
    )
    C = cum_before + R

    in_run = (cum == C[:, None]) & nonempty
    present = occ_q & jnp.any(in_run & (rem == fr[:, None]), axis=1)

    ovf_right = in_run[:, -1]  # run may continue past the window
    ovf_nostart = occ_q & ~ovf_left & (cum[:, -1] < C)  # run start past window
    overflow = occ_q & (ovf_left | ovf_right | ovf_nostart)
    return present, overflow


@functools.partial(jax.jit, static_argnums=(0, 4))
def lookup(
    cfg: QFConfig, state: QFState, fq: jnp.ndarray, fr: jnp.ndarray, window: int = 256
):
    """MAY-CONTAIN for a batch of fingerprints (paper Fig. 3, vectorized).

    Fast path: one contiguous ``2*window``-slot decode per query (the
    TPU analogue of the paper's single-page cluster access).  Queries
    whose cluster exceeds the window (whp-rare; paper §3 Fact) retry at
    4x the window, then fall back to the exact decode path.
    """
    present, ovf = _window_decode(cfg, state, fq, fr, window)

    def retry(args):
        present, ovf = args
        p2, o2 = _window_decode(cfg, state, fq, fr, min(4 * window, cfg.m))
        present = jnp.where(ovf, p2, present)

        def exact(args):
            present, o2 = args
            pe = lookup_exact(cfg, state, fq, fr)
            return jnp.where(o2, pe, present)

        return jax.lax.cond(
            jnp.any(o2), exact, lambda a: a[0], (present, ovf & o2)
        )

    return jax.lax.cond(jnp.any(ovf), retry, lambda a: a[0], (present, ovf))


def contains(cfg: QFConfig, state: QFState, keys: jnp.ndarray, window: int = 256):
    """Key-level MAY-CONTAIN."""
    fq, fr = fingerprints(cfg, keys)
    return lookup(cfg, state, fq, fr, window)


# ---------------------------------------------------------------------------
# Bulk mutation: insert / delete / merge / resize
# ---------------------------------------------------------------------------


def merge_sorted_with(cfg: QFConfig, state: QFState, fq, fr, k, build) -> QFState:
    """insert_sorted body with a pluggable build pass (reference or kernel)."""
    qs, rs, n = extract(cfg, state)
    allq = jnp.concatenate([qs, fq])
    allr = jnp.concatenate([rs, fr])
    valid = jnp.concatenate(
        [jnp.arange(qs.shape[0]) < n, jnp.arange(fq.shape[0]) < jnp.asarray(k)]
    )
    allq, allr = _pad_sort(allq, allr, valid)
    new = build(cfg, allq, allr, n + jnp.asarray(k, jnp.int32))
    return new._replace(overflow=new.overflow | state.overflow)


@functools.partial(jax.jit, static_argnums=0)
def insert_sorted(cfg: QFConfig, state: QFState, fq, fr, k) -> QFState:
    """Insert a sorted batch of k fingerprints (merge + rebuild).

    This is the paper's merge-sort write path: one streaming pass over
    the filter — sequential I/O in the paper, sequential HBM traffic
    here.  Duplicates are kept (QF is a multiset).
    """
    return merge_sorted_with(cfg, state, fq, fr, k, build_sorted)


def insert(cfg: QFConfig, state: QFState, keys: jnp.ndarray, k=None) -> QFState:
    """Insert a batch of keys (k = valid count; default all)."""
    if k is None:
        k = keys.shape[0]
    fq, fr = fingerprints(cfg, keys)
    idx = jnp.arange(keys.shape[0])
    fq, fr = _pad_sort(fq, fr, idx < jnp.asarray(k))
    return insert_sorted(cfg, state, fq, fr, k)


@functools.partial(jax.jit, static_argnums=0)
def delete_sorted(cfg: QFConfig, state: QFState, fq, fr, k) -> QFState:
    """Delete (one copy of) each of k sorted fingerprints — multiset diff."""
    qs, rs, n = extract(cfg, state)
    idx = jnp.arange(qs.shape[0], dtype=jnp.int32)
    valid = idx < n
    # occurrence rank of element i among equal fingerprints
    first = lex_searchsorted(qs, rs, qs, rs, "left")
    rank = idx - first
    # how many copies of this fingerprint are being deleted
    dlo = lex_searchsorted(fq, fr, qs, rs, "left")
    dhi = lex_searchsorted(fq, fr, qs, rs, "right")
    ndel = jnp.minimum(dhi, jnp.asarray(k, jnp.int32)) - jnp.minimum(
        dlo, jnp.asarray(k, jnp.int32)
    )
    keep = valid & (rank >= ndel)
    qs2, rs2 = _pad_sort(qs, rs, keep)
    return build_sorted(cfg, qs2, rs2, jnp.sum(keep, dtype=jnp.int32))


def delete(cfg: QFConfig, state: QFState, keys: jnp.ndarray, k=None) -> QFState:
    if k is None:
        k = keys.shape[0]
    fq, fr = fingerprints(cfg, keys)
    idx = jnp.arange(keys.shape[0])
    fq, fr = _pad_sort(fq, fr, idx < jnp.asarray(k))
    return delete_sorted(cfg, state, fq, fr, k)


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def merge(
    cfg_out: QFConfig,
    cfg_a: QFConfig,
    cfg_b: QFConfig,
    sa: QFState,
    sb: QFState,
) -> QFState:
    """Merge two QFs into a (usually larger) output QF (paper Fig. 5).

    Requires identical fingerprint width: q + r must match across all
    three configs; quotients are re-derived by moving bits between
    quotient and remainder, which preserves sort order.
    """
    pa, pb, po = cfg_a.q + cfg_a.r, cfg_b.q + cfg_b.r, cfg_out.q + cfg_out.r
    if not (pa == pb == po):
        raise ValueError("merge requires equal fingerprint width q + r")
    qa, ra, na = extract(cfg_a, sa)
    qb, rb, nb = extract(cfg_b, sb)
    qa, ra = _requotient(qa, ra, cfg_a, cfg_out)
    qb, rb = _requotient(qb, rb, cfg_b, cfg_out)
    allq = jnp.concatenate([qa, qb])
    allr = jnp.concatenate([ra, rb])
    valid = jnp.concatenate(
        [jnp.arange(qa.shape[0]) < na, jnp.arange(qb.shape[0]) < nb]
    )
    allq, allr = _pad_sort(allq, allr, valid)
    out = build_sorted(cfg_out, allq, allr, na + nb)
    return out._replace(overflow=out.overflow | sa.overflow | sb.overflow)


def _requotient(fq, fr, cfg_in: QFConfig, cfg_out: QFConfig):
    """Move bits between quotient and remainder: (q, r) -> (q', r').

    Monotone w.r.t. lexicographic order, so sortedness is preserved.
    """
    dq = cfg_out.q - cfg_in.q
    if dq == 0:
        return fq, fr
    if dq > 0:  # grow quotient: steal top dq bits of remainder
        top = (fr >> jnp.uint32(cfg_in.r - dq)).astype(jnp.int32)
        fq2 = jnp.where(
            fq == INT32_MAX, INT32_MAX, (fq << dq) | top
        )
        fr2 = jnp.where(
            fq == INT32_MAX,
            UINT32_MAX,
            (fr << jnp.uint32(dq))
            & jnp.uint32((1 << cfg_in.r) - 1 if cfg_in.r < 32 else 0xFFFFFFFF),
        )
        # keep remainder left-aligned in r_out bits: r_out = r_in - dq
        fr2 = fr2 >> jnp.uint32(cfg_in.r - cfg_out.r)
        return fq2, fr2
    # shrink quotient: donate low |dq| quotient bits to the remainder top
    k = -dq
    lowbits = (fq & ((1 << k) - 1)).astype(jnp.uint32)
    fq2 = jnp.where(fq == INT32_MAX, INT32_MAX, fq >> k)
    fr2 = jnp.where(
        fq == INT32_MAX, UINT32_MAX, (lowbits << jnp.uint32(cfg_in.r)) | fr
    )
    return fq2, fr2


def multi_merge(cfg_out: QFConfig, parts, build=None) -> QFState:
    """Merge any number of (cfg, state) QFs into one output QF.

    One decode pass per input + one sort + one build — the k-way
    analogue of the paper's merge, used by the cascade filter when it
    collapses levels Q_0..Q_i into Q_i' (paper §4, Fig. 5).  ``build``
    swaps the bandwidth-bound rebuild pass (default :func:`build_sorted`;
    the Pallas kernel path passes ``kernels.ops.build_sorted``).
    """
    if build is None:
        build = build_sorted
    p_out = cfg_out.q + cfg_out.r
    qs_all, rs_all, valid_all, n_total = [], [], [], jnp.zeros((), jnp.int32)
    overflow = jnp.zeros((), jnp.bool_)
    for cfg, state in parts:
        if cfg.q + cfg.r != p_out:
            raise ValueError("multi_merge requires equal fingerprint width")
        fq, fr, n = extract(cfg, state)
        fq, fr = _requotient(fq, fr, cfg, cfg_out)
        qs_all.append(fq)
        rs_all.append(fr)
        valid_all.append(jnp.arange(fq.shape[0]) < n)
        n_total = n_total + n
        overflow = overflow | state.overflow
    allq = jnp.concatenate(qs_all)
    allr = jnp.concatenate(rs_all)
    valid = jnp.concatenate(valid_all)
    allq, allr = _pad_sort(allq, allr, valid)
    out = build(cfg_out, allq, allr, n_total)
    # an input whose slack had overflowed may already have lost entries;
    # the union must keep reporting that (as qf.merge does)
    return out._replace(overflow=out.overflow | overflow)


def merge_streams(aq, ar, na, bq, br, nb):
    """Merge two lexicographically sorted fingerprint streams in O(n).

    Both inputs follow the extract/_pad_sort convention: sorted valid
    prefix (``na``/``nb`` entries) followed by sentinel padding.  The
    output stream has length ``len(a) + len(b)`` with the ``na + nb``
    valid entries sorted first — computed by rank arithmetic
    (``searchsorted`` + scatter), skipping the ``lax.sort`` that
    dominates ``multi_merge``.  Used by the incremental-resize finish
    pass, where one input (the in-flight buffer) is much smaller than
    the other (the freshly built table).
    """
    la, lb = aq.shape[0], bq.shape[0]
    ia = jnp.arange(la, dtype=jnp.int32)
    ib = jnp.arange(lb, dtype=jnp.int32)
    # ties break a-before-b: a ranks 'left' into b, b ranks 'right' into a
    ra = ia + lex_searchsorted(bq, br, aq, ar, "left")
    rb = ib + lex_searchsorted(aq, ar, bq, br, "right")
    # sentinel padding would collide: route it to the tail deterministically
    ra = jnp.where(ia < na, ra, nb + ia)
    rb = jnp.where(ib < nb, rb, la + ib)
    out_q = jnp.full((la + lb,), INT32_MAX, jnp.int32)
    out_r = jnp.full((la + lb,), UINT32_MAX, jnp.uint32)
    out_q = out_q.at[ra].set(aq).at[rb].set(bq)
    out_r = out_r.at[ra].set(ar).at[rb].set(br)
    return out_q, out_r


def merge_streams_many(parts):
    """Fold any number of sorted streams into one, sort-free.

    ``parts`` is a sequence of ``(fq, fr, n)`` streams in the
    extract/_pad_sort convention (same (q, r) split).  Pairwise
    :func:`merge_streams` folds keep every pass rank arithmetic —
    the k-way analogue used where ``multi_merge`` would pay a
    ``lax.sort`` over the concatenation.  Returns ``(fq, fr, n)`` with
    length ``sum(len(part))``.
    """
    parts = list(parts)
    if not parts:
        raise ValueError("merge_streams_many needs at least one stream")
    aq, ar, na = parts[0]
    na = jnp.asarray(na, jnp.int32)
    for bq, br, nb in parts[1:]:
        nb = jnp.asarray(nb, jnp.int32)
        aq, ar = merge_streams(aq, ar, na, bq, br, nb)
        na = na + nb
    return aq, ar, na


def resize(
    cfg: QFConfig, state: QFState, new_q: int, build=None
) -> tuple[QFConfig, QFState]:
    """Dynamically resize (paper §3 'Resizing'): borrow/steal one or more
    bits between remainder and quotient, preserving all fingerprints.

    A host-level structural op — the slot-plane shapes change — but the
    requotient + rebuild body is one streaming device pass.  ``build``
    swaps the rebuild pass (reference vs Pallas kernel), as in
    :func:`multi_merge`.
    """
    if build is None:
        build = build_sorted
    new_cfg = cfg._replace(q=new_q, r=cfg.q + cfg.r - new_q)
    qs, rs, n = extract(cfg, state)
    qs, rs = _requotient(qs, rs, cfg, new_cfg)
    pad = new_cfg.total_slots - qs.shape[0]
    if pad > 0:
        qs = jnp.concatenate([qs, jnp.full((pad,), INT32_MAX, jnp.int32)])
        rs = jnp.concatenate([rs, jnp.full((pad,), UINT32_MAX, jnp.uint32)])
    elif pad < 0:
        # shrinking: all valid entries must fit; sort pushes pads last
        qs, rs = _pad_sort(qs, rs, jnp.arange(qs.shape[0]) < n)
        qs, rs = qs[: new_cfg.total_slots], rs[: new_cfg.total_slots]
    new = build(new_cfg, qs, rs, n)
    return new_cfg, new._replace(overflow=new.overflow | state.overflow)


# ---------------------------------------------------------------------------
# Item-at-a-time parity wrappers (paper semantics; used by tests)
# ---------------------------------------------------------------------------


def insert_one(cfg: QFConfig, state: QFState, key) -> QFState:
    return insert(cfg, state, jnp.asarray([key]))


def delete_one(cfg: QFConfig, state: QFState, key) -> QFState:
    return delete(cfg, state, jnp.asarray([key]))


def contains_one(cfg: QFConfig, state: QFState, key) -> jnp.ndarray:
    return contains(cfg, state, jnp.asarray([key]))[0]
