"""Buffered quotient filter (paper §4) — legacy host-driven API.

.. deprecated::
    This dataclass is a thin shim over the functional implementation in
    :mod:`repro.filters.buffered` (``repro.filters.make("buffered_qf", ...)``),
    kept for host-driven callers and the historical tests.  New code
    should use the ``repro.filters`` façade: its state is a pure pytree,
    flush triggers are ``lax.cond`` on device scalars, and a whole
    ingest loop jits into one ``lax.scan``.

One QF in RAM buffers inserts; when it hits the paper's 3/4 load it is
flushed into the (much larger) on-"disk" QF by a single sequential
merge.  Lookups check the RAM QF and then perform one random page read
against the disk QF (the cluster fits a page — the paper's headline
locality property).

Amortized insert cost: O(n / (M B)) block writes — every flush streams
the whole disk structure once.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.filters import buffered as fb
from repro.filters.iostats import to_iolog

from . import quotient_filter as qf
from .cost_model import IOLog


@dataclass
class BufferedQuotientFilter:
    ram_cfg: qf.QFConfig
    disk_cfg: qf.QFConfig

    def __post_init__(self):
        if self.ram_cfg.q + self.ram_cfg.r != self.disk_cfg.q + self.disk_cfg.r:
            raise ValueError("RAM and disk QFs must share fingerprint width")
        if self.ram_cfg.seed != self.disk_cfg.seed:
            raise ValueError("RAM and disk QFs must share the hash seed")
        self._fcfg, self._fstate = fb.make(
            ram_q=self.ram_cfg.q,
            disk_q=self.disk_cfg.q,
            p=self.ram_cfg.q + self.ram_cfg.r,
            slack=self.ram_cfg.slack,
            disk_slack=self.disk_cfg.slack,
            seed=self.ram_cfg.seed,
            max_load=self.ram_cfg.max_load,
        )

    # -- state views ---------------------------------------------------------

    @property
    def ram(self) -> qf.QFState:
        return self._fstate.ram

    @property
    def disk(self) -> qf.QFState:
        return self._fstate.disk

    @property
    def io(self) -> IOLog:
        """Host snapshot of the device-resident I/O counters."""
        return to_iolog(self._fstate.io)

    @property
    def count(self) -> int:
        return int(self._fstate.ram.n) + int(self._fstate.disk.n)

    # -- ops -----------------------------------------------------------------

    def insert(self, keys: jnp.ndarray) -> None:
        self._fstate = fb.insert(self._fcfg, self._fstate, keys)

    def flush(self) -> None:
        """Sequential merge of the RAM QF into the disk QF (paper Fig. 5)."""
        self._fstate = fb.flush(self._fcfg, self._fstate)

    def lookup(self, keys: jnp.ndarray) -> jnp.ndarray:
        self._fstate, hit = fb.probe(self._fcfg, self._fstate, keys)
        return hit
