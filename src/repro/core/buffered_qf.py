"""Buffered quotient filter (paper §4).

One QF in RAM buffers inserts; when it hits the paper's 3/4 load it is
flushed into the (much larger) on-"disk" QF by a single sequential
merge.  Lookups check the RAM QF and then perform one random page read
against the disk QF (the cluster fits a page — the paper's headline
locality property).

Amortized insert cost: O(n / (M B)) block writes — every flush streams
the whole disk structure once.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from . import quotient_filter as qf
from .cost_model import IOLog


@dataclass
class BufferedQuotientFilter:
    ram_cfg: qf.QFConfig
    disk_cfg: qf.QFConfig
    io: IOLog = field(default_factory=IOLog)

    def __post_init__(self):
        if self.ram_cfg.q + self.ram_cfg.r != self.disk_cfg.q + self.disk_cfg.r:
            raise ValueError("RAM and disk QFs must share fingerprint width")
        self.ram = qf.empty(self.ram_cfg)
        self.disk = qf.empty(self.disk_cfg)

    @property
    def count(self) -> int:
        return int(self.ram.n) + int(self.disk.n)

    def insert(self, keys: jnp.ndarray) -> None:
        self.ram = qf.insert(self.ram_cfg, self.ram, keys)
        if float(qf.load(self.ram_cfg, self.ram)) >= self.ram_cfg.max_load:
            self.flush()

    def flush(self) -> None:
        """Sequential merge of the RAM QF into the disk QF (paper Fig. 5)."""
        self.disk = qf.merge(
            self.disk_cfg, self.disk_cfg, self.ram_cfg, self.disk, self.ram
        )
        self.ram = qf.empty(self.ram_cfg)
        # stream old disk QF in, write merged QF out
        self.io.seq_read_bytes += self.disk_cfg.size_bytes
        self.io.seq_write_bytes += self.disk_cfg.size_bytes
        self.io.flushes += 1
        self.io.merges += 1

    def lookup(self, keys: jnp.ndarray) -> jnp.ndarray:
        ram_hit = qf.contains(self.ram_cfg, self.ram, keys)
        disk_hit = qf.contains(self.disk_cfg, self.disk, keys)
        # short-circuit: only RAM misses touch the disk (1 page each)
        if int(self.disk.n) > 0:
            self.io.rand_page_reads += int(jnp.sum(~ram_hit))
        return ram_hit | disk_hit
