"""SSD-oriented Bloom-filter variants used as baselines (paper §2).

* **EBF** — elevator Bloom filter: plain BF + RAM buffer of pending bit
  writes, flushed in sorted (elevator) page order when the buffer
  fills.  Lookups are immediate.
* **BBF** — buffered Bloom filter [Canim et al.]: *hash localization*
  (all k bits of one key land in a single erase-block-sized region)
  plus per-block sub-buffers flushed with one block write.
* **FBF** — forest-structured Bloom filter [Lu et al.]: an in-RAM BF
  first; once RAM fills it is sealed to disk and a forest of
  block-localized on-disk BFs grows; lookups probe every sealed layer.

Membership is computed exactly on device (no false negatives); the
**I/O schedule** each policy would generate on the paper's SSD is
accounted in an :class:`~repro.core.cost_model.IOLog`, from which the
benchmarks derive modeled throughput.  This mirrors how the paper's
numbers bottom out in random-read/write page counts (§5.2).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from . import bloom
from .cost_model import IOLog


def _unique_prefix_pages(pages: np.ndarray, prefix: np.ndarray) -> int:
    """Sum over rows of #unique values among the first prefix[i] entries."""
    B, k = pages.shape
    total = 0
    cols = np.arange(k)
    live = cols[None, :] < prefix[:, None]  # (B, k)
    # is_new[b, j] = pages[b, j] not among pages[b, :j]
    eq = pages[:, :, None] == pages[:, None, :]  # (B, k, k)
    seen_before = np.tril(np.ones((k, k), bool), -1)[None]
    dup = np.any(eq & seen_before, axis=2)
    total = int(np.sum(live & ~dup))
    return total


# ---------------------------------------------------------------------------
# EBF
# ---------------------------------------------------------------------------


@dataclass
class ElevatorBloomFilter:
    cfg: bloom.BloomConfig
    buffer_capacity_bits: int  # RAM budget in pending bit-writes
    io: IOLog = field(default_factory=IOLog)

    def __post_init__(self):
        self.bits = bloom.empty(self.cfg)
        self._pending: list[np.ndarray] = []
        self._pending_count = 0
        self.page_bits = 4096 * 8

    def insert(self, keys: jnp.ndarray) -> None:
        idx = np.asarray(bloom.bit_indices(self.cfg, keys)).reshape(-1)
        self.bits = bloom.insert(self.cfg, self.bits, keys)  # logical state
        self._pending.append(idx)
        self._pending_count += idx.size
        if self._pending_count >= self.buffer_capacity_bits:
            self._flush()

    def _flush(self) -> None:
        if not self._pending:
            return
        allidx = np.concatenate(self._pending)
        pages = np.unique(allidx // self.page_bits)
        # elevator order: one sorted sweep; SSD still charges per-page writes
        self.io.rand_page_writes += int(pages.size)
        self.io.flushes += 1
        self._pending = []
        self._pending_count = 0

    def lookup(self, keys: jnp.ndarray) -> jnp.ndarray:
        hit = bloom.lookup(self.cfg, self.bits, keys)
        probes, idx = bloom.probes_until_reject(self.cfg, self.bits, keys)
        pages = np.asarray(idx) // self.page_bits
        self.io.rand_page_reads += _unique_prefix_pages(
            pages, np.asarray(probes)
        )
        return hit


# ---------------------------------------------------------------------------
# BBF
# ---------------------------------------------------------------------------


@dataclass
class BufferedBloomFilter:
    cfg: bloom.BloomConfig
    ram_bytes: int
    block_bytes: int = 256 * 1024  # erase block (paper's recommended setting)
    page_bytes: int = 4096
    io: IOLog = field(default_factory=IOLog)

    def __post_init__(self):
        self.block_bits = self.block_bytes * 8
        self.n_blocks = max(1, self.cfg.m_bits // self.block_bits)
        self.bits = bloom.empty(self.cfg)
        # per-block sub-buffers: equal division of RAM (paper §2)
        per_block_bytes = max(64, self.ram_bytes // self.n_blocks)
        self.subbuf_capacity = max(8, per_block_bytes // 4)  # 4B per pending op
        self._subbuf_counts = np.zeros(self.n_blocks, np.int64)

    def _localized_indices(self, keys: jnp.ndarray) -> np.ndarray:
        """Hash localization: block via h0, k bits inside the block."""
        k32 = keys.astype(jnp.uint32)
        blk = (
            np.asarray(bloom.fmix32(k32 ^ jnp.uint32(0xB10C)), np.int64)
            % self.n_blocks
        )
        inner = np.asarray(
            bloom.bit_indices(self.cfg._replace(m_bits=self.block_bits), keys)
        )
        return blk[:, None] * self.block_bits + inner, blk

    def insert(self, keys: jnp.ndarray) -> None:
        idx, blk = self._localized_indices(keys)
        flat = jnp.asarray(idx.reshape(-1) % self.cfg.m_bits)
        self.bits = self.bits.at[flat].max(jnp.uint8(1))
        np.add.at(self._subbuf_counts, blk, self.cfg.k)
        full = np.nonzero(self._subbuf_counts >= self.subbuf_capacity)[0]
        for _ in full:
            self.io.rand_page_writes += 1
            self.io.seq_write_bytes += self.block_bytes
            self.io.flushes += 1
        self._subbuf_counts[full] = 0

    def lookup(self, keys: jnp.ndarray) -> jnp.ndarray:
        idx, _ = self._localized_indices(keys)
        flat = jnp.asarray(idx % self.cfg.m_bits)
        vals = self.bits[flat] > 0
        hit = jnp.all(vals, axis=1)
        # short-circuit probes; bits localized to one block but spread
        # across its 4 KiB read pages (sorted probe order, OS prefetch
        # per the paper — still distinct page reads)
        valsn = np.asarray(vals)
        anyz = np.any(~valsn, axis=1)
        first0 = np.argmax(~valsn, axis=1)
        probes = np.where(anyz, first0 + 1, self.cfg.k)
        pages = idx // (self.page_bytes * 8)
        self.io.rand_page_reads += _unique_prefix_pages(pages, probes)
        return hit


# ---------------------------------------------------------------------------
# FBF
# ---------------------------------------------------------------------------


@dataclass
class ForestBloomFilter:
    bits_per_element: float
    ram_bytes: int
    total_elements: int  # sizing hint for the on-disk layers
    seed: int = 0
    block_bytes: int = 256 * 1024
    page_bytes: int = 4096
    io: IOLog = field(default_factory=IOLog)

    def __post_init__(self):
        k = bloom.optimal_k(self.bits_per_element)
        ram_bits = self.ram_bytes * 8
        self.ram_cfg = bloom.BloomConfig(m_bits=ram_bits, k=k, seed=self.seed)
        self.ram_bits_arr = bloom.empty(self.ram_cfg)
        self.ram_count = 0
        self.ram_capacity = int(ram_bits / self.bits_per_element)
        self.layers: list[tuple[bloom.BloomConfig, jnp.ndarray]] = []
        self._layer_seed = self.seed + 1
        self._active_subbuf = 0
        self.subbuf_capacity = max(8, (self.ram_bytes // 8) // 4)

    def _seal_ram(self) -> None:
        """RAM BF is full: write it to disk as a new forest layer."""
        self.layers.append((self.ram_cfg, self.ram_bits_arr))
        self.io.seq_write_bytes += self.ram_cfg.m_bits // 8
        self.io.flushes += 1
        self._layer_seed += 1
        self.ram_cfg = self.ram_cfg._replace(seed=self._layer_seed)
        self.ram_bits_arr = bloom.empty(self.ram_cfg)
        self.ram_count = 0

    def insert(self, keys: jnp.ndarray) -> None:
        n = int(keys.shape[0])
        self.ram_bits_arr = bloom.insert(self.ram_cfg, self.ram_bits_arr, keys)
        self.ram_count += n
        if len(self.layers) > 0:
            # post-spill phase: inserts also cost buffered block writes
            # (space stealing delays them; amortized accounting)
            self._active_subbuf += n * self.ram_cfg.k
            while self._active_subbuf >= self.subbuf_capacity:
                self.io.rand_page_writes += 1
                self.io.seq_write_bytes += self.block_bytes
                self._active_subbuf -= self.subbuf_capacity
        if self.ram_count >= self.ram_capacity:
            self._seal_ram()

    def lookup(self, keys: jnp.ndarray) -> jnp.ndarray:
        hit = bloom.lookup(self.ram_cfg, self.ram_bits_arr, keys)
        pending = ~np.asarray(hit)
        out = np.asarray(hit).copy()
        for cfg, arr in self.layers:
            if not pending.any():
                break
            sub = jnp.asarray(np.nonzero(pending)[0])
            lhit = np.asarray(bloom.lookup(cfg, arr, jnp.asarray(keys)[sub]))
            # block localization => ~1 page read per probed layer
            self.io.rand_page_reads += int(pending.sum())
            out[np.asarray(sub)[lhit]] = True
            pending[np.asarray(sub)[lhit]] = False
        return jnp.asarray(out)
