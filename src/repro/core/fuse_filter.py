"""Binary-fuse (3-wise xor) filter core: the frozen cold tier.

Graf & Lemire's xor / binary-fuse filters trade the quotient filter's
mutability for ~20-30% smaller tables and a probe of exactly three
independent reads: each key maps to one cell in each of three
*consecutive* segments, and membership is
``fp(x) == T[h0] ^ T[h1] ^ T[h2]``.  A cascade level below Q0 is
write-once between merge-downs — exactly the immutability this layout
needs — so the cascade's ``frozen_below`` mode (``repro.filters.cascade``)
demotes merged-down levels into this form.

Construction is peeling-based and fully device-resident
(:func:`freeze_stream` is traceable; the data-dependent round count
lives in ``lax.while_loop`` carries, not host control flow):

* **parallel-round peel** — the 3-uniform hypergraph over the
  deduplicated fingerprints is peeled in rounds (all keys incident to a
  degree-1 cell per round; O(log n) rounds whp), recording each key's
  peel round and assigned cell;
* **reverse-round replay** — each round is then one gather + xor +
  masked scatter batch over the table, replayed in reverse round order.
  Within a round, assigned cells are provably disjoint from the cells
  any same-round key reads (a degree-1 cell is incident to exactly one
  alive key), so the batch is exact.

Seed retries on a 2-core ride in an outer ``while_loop``; a set that
still will not peel after :data:`MAX_PEEL_ATTEMPTS` seeds sets the
state's ``overflow`` flag (the protocol's poisoned-but-correct-shape
convention) instead of raising, so frozen construction can run under
``jit`` from the cascade's merge-down path.  Host entry points
(``freeze``/``freeze_keys``) still raise on concrete capacity overflow.

Because an AMQ cannot re-enumerate its members, a frozen level also
retains its sorted fingerprint *run* (the stream a merge would read) so
a later merge-down that consumes the level re-expands it exactly — the
run is sequential-only cold bytes, never touched by probes; the probe
tier is the fuse table alone.  Geometry (segment sizing, expansion
factor, fp-bit matching) comes from :mod:`repro.core.cost_model`.

States are pure pytrees; ``lookup_fp`` is jittable (the per-state retry
seed rides in the state as a device scalar).  Fingerprints are carried
in the *canonical split* of the p-bit space (``canonical_split``) so
streams from cascade levels with different (q, r) splits, and standalone
key sets, all hash identically.
"""

from __future__ import annotations

import functools
import operator
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import cost_model
from .fingerprint import fingerprint, fmix32
from .quotient_filter import INT32_MAX, UINT32_MAX

_GOLD1 = jnp.uint32(0x9E3779B9)
_GOLD2 = jnp.uint32(0x85EBCA77)
_MUL1 = jnp.uint32(0xC2B2AE3D)
_MUL2 = jnp.uint32(0x27D4EB2F)

#: host-level construction retries (fresh hash seed each) before giving up
MAX_PEEL_ATTEMPTS = 32


def canonical_split(p: int) -> tuple[int, int]:
    """The (q, r) split every fuse-filter stream is carried in.

    Any level's (q, r) split of the same p re-quotients to this one
    losslessly (``quotient_filter._requotient``), so runs from different
    cascade depths concatenate and hash consistently.
    """
    if not (2 <= p <= 62):
        raise ValueError(f"fingerprint bits p must be in [2, 62], got {p}")
    r = min(32, p - 1)
    return p - r, r


class FuseConfig(NamedTuple):
    """Static binary-fuse geometry (hashable; jit-static)."""

    p: int  # input fingerprint bits (shared with the QF families)
    fp_bits: int  # stored cell width f: fp rate ~= 2**-f
    segment_length: int  # power of two
    segment_count: int  # >= 1 (arbitrary; start picked by mulhi)
    capacity: int  # max multiset size (run storage length)
    seed: int = 0  # key->fingerprint seed (matches the QF families)

    @property
    def slots(self) -> int:
        return (self.segment_count + 2) * self.segment_length

    @property
    def size_bytes(self) -> int:
        """Modeled probe-structure size: fp_bits per cell."""
        return (self.slots * self.fp_bits + 7) // 8

    @property
    def run_bytes(self) -> int:
        """Modeled retained-run size: p bits per stored fingerprint.

        Sequential-only cold bytes — read by merges, never by probes.
        """
        return (self.capacity * self.p + 7) // 8

    @property
    def canon(self) -> tuple[int, int]:
        return canonical_split(self.p)


def make_config(
    capacity: int,
    p: int,
    fp_bits: int | None = None,
    seed: int = 0,
    segment_length: int | None = None,
) -> FuseConfig:
    """Size a fuse table for ``capacity`` keys via the cost-model geometry."""
    if capacity < 1:
        raise ValueError("capacity must be >= 1")
    canonical_split(p)  # validates p
    L = segment_length or cost_model.fuse_segment_length(capacity)
    if L & (L - 1) or L < 2:
        raise ValueError("segment_length must be a power of two >= 2")
    C = cost_model.fuse_segment_count(capacity, L)
    if C >= 1 << 15:
        raise ValueError("segment_count too large for the 32-bit start mix")
    if fp_bits is None:
        fp_bits = cost_model.fuse_fp_bits_for(min(32, p - 1))
    if not (1 <= fp_bits <= 28):
        raise ValueError(f"fp_bits must be in [1, 28], got {fp_bits}")
    return FuseConfig(
        p=p,
        fp_bits=fp_bits,
        segment_length=L,
        segment_count=C,
        capacity=capacity,
        seed=seed,
    )


class FuseState(NamedTuple):
    """Device state of one frozen level (pure pytree).

    ``table`` is the probe structure; ``run_q``/``run_r`` the retained
    sorted fingerprint run in the canonical split (sentinel-padded to
    ``cfg.capacity``); ``fuse_seed`` the construction seed that peeled
    (a device scalar so probes stay jittable across retries).
    """

    table: jnp.ndarray  # uint32 (slots,)
    run_q: jnp.ndarray  # int32 (capacity,) canonical quotients, sorted
    run_r: jnp.ndarray  # uint32 (capacity,) canonical remainders
    n: jnp.ndarray  # int32 scalar, multiset size
    n_unique: jnp.ndarray  # int32 scalar
    fuse_seed: jnp.ndarray  # int32 scalar
    overflow: jnp.ndarray  # bool scalar (capacity exceeded upstream)


def empty(cfg: FuseConfig) -> FuseState:
    return FuseState(
        table=jnp.zeros((cfg.slots,), jnp.uint32),
        run_q=jnp.full((cfg.capacity,), INT32_MAX, jnp.int32),
        run_r=jnp.full((cfg.capacity,), UINT32_MAX, jnp.uint32),
        n=jnp.zeros((), jnp.int32),
        n_unique=jnp.zeros((), jnp.int32),
        fuse_seed=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.bool_),
    )


# ---------------------------------------------------------------------------
# Hashing: canonical fingerprint -> (3 cell positions, stored fp)
# ---------------------------------------------------------------------------


def _mulhi_seg(x: jnp.ndarray, m: int) -> jnp.ndarray:
    """floor(x * m / 2**32) for uint32 lanes and python int m < 2**15."""
    mm = jnp.uint32(m)
    lo = (x & jnp.uint32(0xFFFF)) * mm
    hi = (x >> jnp.uint32(16)) * mm
    return (hi + (lo >> jnp.uint32(16))) >> jnp.uint32(16)


def fuse_hash(cfg: FuseConfig, fq, fr, fuse_seed):
    """Canonical-split fingerprints -> (pos0, pos1, pos2, fp).

    Positions are cells in three *consecutive* segments
    ``start .. start+2`` — the locality the batched probe kernel tiles.
    ``fuse_seed`` may be a device scalar (construction retries).
    """
    L = cfg.segment_length
    s = jnp.asarray(fuse_seed).astype(jnp.uint32)
    a = fmix32(jnp.asarray(fq).astype(jnp.uint32) ^ fmix32(s ^ _GOLD1))
    b = fmix32(jnp.asarray(fr).astype(jnp.uint32) ^ fmix32(s + _GOLD2))
    h1 = fmix32(a ^ (b * _MUL1))
    h2 = fmix32(b + (a * _MUL2))
    h3 = fmix32(h1 ^ (h2 * _MUL1))
    h4 = fmix32(h2 ^ (h3 * _MUL2))

    start = _mulhi_seg(h1, cfg.segment_count).astype(jnp.int32)
    mask = jnp.uint32(L - 1)
    off0 = (h2 & mask).astype(jnp.int32)
    off1 = ((h2 >> jnp.uint32(16)) & mask).astype(jnp.int32)
    off2 = (h3 & mask).astype(jnp.int32)
    fp = h4 >> jnp.uint32(32 - cfg.fp_bits)

    p0 = start * L + off0
    p1 = (start + 1) * L + off1
    p2 = (start + 2) * L + off2
    return p0, p1, p2, fp


def key_fingerprints(cfg: FuseConfig, keys: jnp.ndarray):
    """Keys -> canonical-split fingerprints (same hash as the QF families)."""
    qc, rc = cfg.canon
    return fingerprint(keys, qc, rc, cfg.seed)


# ---------------------------------------------------------------------------
# Construction: device-resident parallel peel + reverse-round replay
# ---------------------------------------------------------------------------


def _fit_plane(x, cap: int, fill, dtype) -> jnp.ndarray:
    """Slice/pad a stream plane to exactly ``cap`` lanes (static shapes)."""
    x = jnp.asarray(x).astype(dtype)[:cap]
    pad = cap - x.shape[0]
    if pad > 0:
        x = jnp.concatenate([x, jnp.full((pad,), fill, dtype)])
    return x


def _peel_assign(cfg: FuseConfig, alive0, p0, p1, p2, fp):
    """Peel one seed's hypergraph and replay the table assignment.

    Everything is masked, fixed-shape device work: the peel
    ``while_loop`` records (round, cell) per key; the replay
    ``fori_loop`` walks rounds in reverse, and within a round the
    scatter targets are provably disjoint from the cells any same-round
    key reads (a degree-1 cell is incident to exactly one alive key).
    Returns ``(ok, table)`` — ``ok`` False means this seed has a 2-core.
    """
    cap = alive0.shape[0]
    drop = jnp.int32(cfg.slots)  # OOB index: mode="drop" discards the lane

    deg = jnp.zeros((cfg.slots,), jnp.int32)
    for p in (p0, p1, p2):
        deg = deg.at[jnp.where(alive0, p, drop)].add(1, mode="drop")

    def _peel_cond(carry):
        _, alive, _, _, _, progressed = carry
        return jnp.any(alive) & progressed

    def _peel_body(carry):
        deg, alive, round_of, cell_of, rnd, _ = carry
        single = deg == 1
        can = alive & (single[p0] | single[p1] | single[p2])
        cell = jnp.where(single[p0], p0, jnp.where(single[p1], p1, p2))
        round_of = jnp.where(can, rnd, round_of)
        cell_of = jnp.where(can, cell, cell_of)
        for p in (p0, p1, p2):
            deg = deg.at[jnp.where(can, p, drop)].add(-1, mode="drop")
        return deg, alive & ~can, round_of, cell_of, rnd + 1, jnp.any(can)

    deg, alive, round_of, cell_of, rounds, _ = jax.lax.while_loop(
        _peel_cond,
        _peel_body,
        (
            deg,
            alive0,
            jnp.full((cap,), -1, jnp.int32),
            jnp.full((cap,), drop, jnp.int32),
            jnp.zeros((), jnp.int32),
            jnp.ones((), jnp.bool_),
        ),
    )
    ok = ~jnp.any(alive)

    def _replay(i, table):
        m = round_of == rounds - 1 - i
        v = fp ^ table[p0] ^ table[p1] ^ table[p2]
        return table.at[jnp.where(m, cell_of, drop)].set(v, mode="drop")

    table = jax.lax.fori_loop(
        0, rounds, _replay, jnp.zeros((cfg.slots,), jnp.uint32)
    )
    return ok, jnp.where(ok, table, jnp.zeros_like(table))


@functools.partial(jax.jit, static_argnums=(0, 4))
def _freeze_impl(cfg: FuseConfig, nq, nr, n, max_attempts: int):
    lane = jnp.arange(cfg.capacity, dtype=jnp.int32)
    overflow = n > cfg.capacity
    n = jnp.minimum(n.astype(jnp.int32), jnp.int32(cfg.capacity))
    valid = lane < n
    nq = jnp.where(valid, nq, INT32_MAX)
    nr = jnp.where(valid, nr, UINT32_MAX)

    # dedup: identical p-bit fingerprints are one hyperedge (membership
    # is identical; the run keeps the multiset for merges/stats)
    keep = valid & jnp.concatenate(
        [jnp.ones((1,), bool), (nq[1:] != nq[:-1]) | (nr[1:] != nr[:-1])]
    )
    nu = jnp.sum(keep).astype(jnp.int32)

    # retry loop: fresh hash seed per attempt until the graph peels
    base = (cfg.seed * 0x9E3779B1) & 0xFFFFFFFF  # static part of the schedule

    def _try_cond(carry):
        attempt, ok, _, _ = carry
        return ~ok & (attempt < max_attempts)

    def _try_body(carry):
        attempt, _, _, _ = carry
        fuse_seed = (
            jnp.uint32(base) + attempt.astype(jnp.uint32) * jnp.uint32(0x85EBCA6B)
        ) & jnp.uint32(0x7FFFFFFF)
        p0, p1, p2, fp = fuse_hash(cfg, nq, nr, fuse_seed)
        ok, table = _peel_assign(cfg, keep, p0, p1, p2, fp)
        return attempt + 1, ok, table, fuse_seed.astype(jnp.int32)

    _, ok, table, fuse_seed = jax.lax.while_loop(
        _try_cond,
        _try_body,
        (
            jnp.zeros((), jnp.int32),
            nu == 0,  # the empty set "peels" with seed 0 and a zero table
            jnp.zeros((cfg.slots,), jnp.uint32),
            jnp.zeros((), jnp.int32),
        ),
    )

    return FuseState(
        table=table,
        run_q=nq,
        run_r=nr,
        n=n,
        n_unique=nu,
        fuse_seed=fuse_seed,
        overflow=overflow | ~ok,
    )


def freeze_stream(
    cfg: FuseConfig, fq, fr, n, max_attempts: int = MAX_PEEL_ATTEMPTS
) -> FuseState:
    """Build a frozen filter from a sorted canonical fingerprint stream.

    ``(fq, fr)`` follow the extract/_pad_sort convention: first ``n``
    entries are the lexicographically sorted multiset, padding is
    sentinels.  Fully traceable (``n`` may be a device scalar): the
    data-dependent peel rounds and seed retries run as ``while_loop``
    carries.  A stream that exceeds ``cfg.capacity`` or a 2-core that
    survives every retry sets ``overflow`` instead of raising.
    """
    return _freeze_impl(
        cfg,
        _fit_plane(fq, cfg.capacity, INT32_MAX, jnp.int32),
        _fit_plane(fr, cfg.capacity, UINT32_MAX, jnp.uint32),
        jnp.asarray(n, jnp.int32),
        max_attempts,
    )


def freeze(cfg: FuseConfig, fq, fr, n, max_attempts: int = MAX_PEEL_ATTEMPTS):
    """Host entry point: :func:`freeze_stream` with concrete-``n`` checks.

    Raises on capacity overflow (``n`` must be a host scalar here) so
    structural callers fail loudly instead of propagating a poisoned
    state; traced callers use :func:`freeze_stream` directly.
    """
    n = operator.index(n)
    if n > cfg.capacity:
        raise ValueError(
            f"stream of {n} fingerprints exceeds frozen capacity "
            f"{cfg.capacity}; grow/resize the level first"
        )
    return freeze_stream(cfg, fq, fr, n, max_attempts)


def freeze_keys(cfg: FuseConfig, keys: jnp.ndarray) -> FuseState:
    """Freeze a raw key batch (standalone construction path)."""
    if keys.shape[0] > cfg.capacity:
        raise ValueError(
            f"stream of {keys.shape[0]} fingerprints exceeds frozen capacity "
            f"{cfg.capacity}; grow/resize the level first"
        )
    fq, fr = key_fingerprints(cfg, keys)
    fq, fr = jax.lax.sort((fq.astype(jnp.int32), fr), num_keys=2)
    return freeze_stream(cfg, fq, fr, keys.shape[0])


# ---------------------------------------------------------------------------
# Probe (reference; the Pallas path lives in repro.kernels)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=0)
def lookup_fp(cfg: FuseConfig, state: FuseState, fq, fr):
    """MAY-CONTAIN for canonical-split fingerprints: 3 gathers + xor.

    Jitted with the config static (the quotient_filter idiom): an eager
    façade ``contains`` compiles once per (cfg, batch shape) instead of
    dispatching the whole hash + 3-gather chain op by op per call.
    """
    p0, p1, p2, fp = fuse_hash(cfg, fq, fr, state.fuse_seed)
    got = state.table[p0] ^ state.table[p1] ^ state.table[p2]
    return (state.n > 0) & (got == fp)


@functools.partial(jax.jit, static_argnums=0)
def contains(cfg: FuseConfig, state: FuseState, keys: jnp.ndarray):
    fq, fr = key_fingerprints(cfg, keys)
    return lookup_fp(cfg, state, fq, fr)


def extract_run(cfg: FuseConfig, state: FuseState):
    """The stored sorted run: ``(fq, fr, n)`` in the canonical split.

    This is the re-expansion path: a merge that consumes a frozen level
    streams these fingerprints back out exactly (the QF ``extract``
    analogue, without a decode — the run is stored directly).
    """
    return state.run_q, state.run_r, state.n
