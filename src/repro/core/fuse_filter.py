"""Binary-fuse (3-wise xor) filter core: the frozen cold tier.

Graf & Lemire's xor / binary-fuse filters trade the quotient filter's
mutability for ~20-30% smaller tables and a probe of exactly three
independent reads: each key maps to one cell in each of three
*consecutive* segments, and membership is
``fp(x) == T[h0] ^ T[h1] ^ T[h2]``.  A cascade level below Q0 is
write-once between merge-downs — exactly the immutability this layout
needs — so the cascade's ``frozen_below`` mode (``repro.filters.cascade``)
demotes merged-down levels into this form.

Construction is peeling-based and split across the hierarchy the way
the paper splits its own maintenance work:

* **host-side peel ordering** — the 3-uniform hypergraph over the
  deduplicated fingerprints is peeled in *parallel rounds* (all keys
  incident to a degree-1 cell per round; O(log n) rounds whp), a
  data-dependent loop that cannot live under ``jit``;
* **device-side batched assignment** — each round is then one gather +
  xor + scatter batch over the table, replayed in reverse round order.
  Within a round, assigned cells are provably disjoint from the cells
  any same-round key reads (a degree-1 cell is incident to exactly one
  alive key), so the batch is exact.

Because an AMQ cannot re-enumerate its members, a frozen level also
retains its sorted fingerprint *run* (the stream a merge would read) so
a later merge-down that consumes the level re-expands it exactly — the
run is sequential-only cold bytes, never touched by probes; the probe
tier is the fuse table alone.  Geometry (segment sizing, expansion
factor, fp-bit matching) comes from :mod:`repro.core.cost_model`.

States are pure pytrees; ``lookup_fp`` is jittable (the per-state retry
seed rides in the state as a device scalar).  Fingerprints are carried
in the *canonical split* of the p-bit space (``canonical_split``) so
streams from cascade levels with different (q, r) splits, and standalone
key sets, all hash identically.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from . import cost_model
from .fingerprint import fingerprint, fmix32
from .quotient_filter import INT32_MAX, UINT32_MAX

_GOLD1 = jnp.uint32(0x9E3779B9)
_GOLD2 = jnp.uint32(0x85EBCA77)
_MUL1 = jnp.uint32(0xC2B2AE3D)
_MUL2 = jnp.uint32(0x27D4EB2F)

#: host-level construction retries (fresh hash seed each) before giving up
MAX_PEEL_ATTEMPTS = 32


def canonical_split(p: int) -> tuple[int, int]:
    """The (q, r) split every fuse-filter stream is carried in.

    Any level's (q, r) split of the same p re-quotients to this one
    losslessly (``quotient_filter._requotient``), so runs from different
    cascade depths concatenate and hash consistently.
    """
    if not (2 <= p <= 62):
        raise ValueError(f"fingerprint bits p must be in [2, 62], got {p}")
    r = min(32, p - 1)
    return p - r, r


class FuseConfig(NamedTuple):
    """Static binary-fuse geometry (hashable; jit-static)."""

    p: int  # input fingerprint bits (shared with the QF families)
    fp_bits: int  # stored cell width f: fp rate ~= 2**-f
    segment_length: int  # power of two
    segment_count: int  # >= 1 (arbitrary; start picked by mulhi)
    capacity: int  # max multiset size (run storage length)
    seed: int = 0  # key->fingerprint seed (matches the QF families)

    @property
    def slots(self) -> int:
        return (self.segment_count + 2) * self.segment_length

    @property
    def size_bytes(self) -> int:
        """Modeled probe-structure size: fp_bits per cell."""
        return (self.slots * self.fp_bits + 7) // 8

    @property
    def run_bytes(self) -> int:
        """Modeled retained-run size: p bits per stored fingerprint.

        Sequential-only cold bytes — read by merges, never by probes.
        """
        return (self.capacity * self.p + 7) // 8

    @property
    def canon(self) -> tuple[int, int]:
        return canonical_split(self.p)


def make_config(
    capacity: int,
    p: int,
    fp_bits: int | None = None,
    seed: int = 0,
    segment_length: int | None = None,
) -> FuseConfig:
    """Size a fuse table for ``capacity`` keys via the cost-model geometry."""
    if capacity < 1:
        raise ValueError("capacity must be >= 1")
    canonical_split(p)  # validates p
    L = segment_length or cost_model.fuse_segment_length(capacity)
    if L & (L - 1) or L < 2:
        raise ValueError("segment_length must be a power of two >= 2")
    C = cost_model.fuse_segment_count(capacity, L)
    if C >= 1 << 15:
        raise ValueError("segment_count too large for the 32-bit start mix")
    if fp_bits is None:
        fp_bits = cost_model.fuse_fp_bits_for(min(32, p - 1))
    if not (1 <= fp_bits <= 28):
        raise ValueError(f"fp_bits must be in [1, 28], got {fp_bits}")
    return FuseConfig(
        p=p,
        fp_bits=fp_bits,
        segment_length=L,
        segment_count=C,
        capacity=capacity,
        seed=seed,
    )


class FuseState(NamedTuple):
    """Device state of one frozen level (pure pytree).

    ``table`` is the probe structure; ``run_q``/``run_r`` the retained
    sorted fingerprint run in the canonical split (sentinel-padded to
    ``cfg.capacity``); ``fuse_seed`` the construction seed that peeled
    (a device scalar so probes stay jittable across retries).
    """

    table: jnp.ndarray  # uint32 (slots,)
    run_q: jnp.ndarray  # int32 (capacity,) canonical quotients, sorted
    run_r: jnp.ndarray  # uint32 (capacity,) canonical remainders
    n: jnp.ndarray  # int32 scalar, multiset size
    n_unique: jnp.ndarray  # int32 scalar
    fuse_seed: jnp.ndarray  # int32 scalar
    overflow: jnp.ndarray  # bool scalar (capacity exceeded upstream)


def empty(cfg: FuseConfig) -> FuseState:
    return FuseState(
        table=jnp.zeros((cfg.slots,), jnp.uint32),
        run_q=jnp.full((cfg.capacity,), INT32_MAX, jnp.int32),
        run_r=jnp.full((cfg.capacity,), UINT32_MAX, jnp.uint32),
        n=jnp.zeros((), jnp.int32),
        n_unique=jnp.zeros((), jnp.int32),
        fuse_seed=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.bool_),
    )


# ---------------------------------------------------------------------------
# Hashing: canonical fingerprint -> (3 cell positions, stored fp)
# ---------------------------------------------------------------------------


def _mulhi_seg(x: jnp.ndarray, m: int) -> jnp.ndarray:
    """floor(x * m / 2**32) for uint32 lanes and python int m < 2**15."""
    mm = jnp.uint32(m)
    lo = (x & jnp.uint32(0xFFFF)) * mm
    hi = (x >> jnp.uint32(16)) * mm
    return (hi + (lo >> jnp.uint32(16))) >> jnp.uint32(16)


def fuse_hash(cfg: FuseConfig, fq, fr, fuse_seed):
    """Canonical-split fingerprints -> (pos0, pos1, pos2, fp).

    Positions are cells in three *consecutive* segments
    ``start .. start+2`` — the locality the batched probe kernel tiles.
    ``fuse_seed`` may be a device scalar (construction retries).
    """
    L = cfg.segment_length
    s = jnp.asarray(fuse_seed).astype(jnp.uint32)
    a = fmix32(jnp.asarray(fq).astype(jnp.uint32) ^ fmix32(s ^ _GOLD1))
    b = fmix32(jnp.asarray(fr).astype(jnp.uint32) ^ fmix32(s + _GOLD2))
    h1 = fmix32(a ^ (b * _MUL1))
    h2 = fmix32(b + (a * _MUL2))
    h3 = fmix32(h1 ^ (h2 * _MUL1))
    h4 = fmix32(h2 ^ (h3 * _MUL2))

    start = _mulhi_seg(h1, cfg.segment_count).astype(jnp.int32)
    mask = jnp.uint32(L - 1)
    off0 = (h2 & mask).astype(jnp.int32)
    off1 = ((h2 >> jnp.uint32(16)) & mask).astype(jnp.int32)
    off2 = (h3 & mask).astype(jnp.int32)
    fp = h4 >> jnp.uint32(32 - cfg.fp_bits)

    p0 = start * L + off0
    p1 = (start + 1) * L + off1
    p2 = (start + 2) * L + off2
    return p0, p1, p2, fp


def key_fingerprints(cfg: FuseConfig, keys: jnp.ndarray):
    """Keys -> canonical-split fingerprints (same hash as the QF families)."""
    qc, rc = cfg.canon
    return fingerprint(keys, qc, rc, cfg.seed)


# ---------------------------------------------------------------------------
# Construction: host-side parallel peel + device-side batched assignment
# ---------------------------------------------------------------------------


def _peel_rounds(h0, h1, h2, slots: int):
    """Parallel peeling of the 3-uniform hypergraph (host, numpy).

    Returns a list of (key_indices, assigned_cell) rounds in peel order,
    or None when the graph has a 2-core (caller retries with a new seed).
    Each round removes every key incident to a degree-1 cell; random
    hypergraphs below the peeling threshold drain in O(log n) rounds.
    """
    nu = h0.shape[0]
    deg = np.zeros(slots, np.int64)
    for h in (h0, h1, h2):
        np.add.at(deg, h, 1)
    alive = np.ones(nu, bool)
    rounds = []
    remaining = nu
    while remaining:
        single = deg == 1
        can = alive & (single[h0] | single[h1] | single[h2])
        idx = np.nonzero(can)[0]
        if idx.size == 0:
            return None  # 2-core: this seed cannot peel
        s0, s1, s2 = h0[idx], h1[idx], h2[idx]
        cell = np.where(single[s0], s0, np.where(single[s1], s1, s2))
        rounds.append((idx, cell))
        alive[idx] = False
        remaining -= idx.size
        for h in (s0, s1, s2):
            np.add.at(deg, h, -1)
    return rounds


def freeze(cfg: FuseConfig, fq, fr, n, max_attempts: int = MAX_PEEL_ATTEMPTS):
    """Build a frozen filter from a sorted canonical fingerprint stream.

    ``(fq, fr)`` follow the extract/_pad_sort convention: first ``n``
    entries are the lexicographically sorted multiset, padding is
    sentinels.  Host-level (the peel order is data-dependent), like the
    protocol's other structural ops; the per-round assignment batches
    run on device.  Retries fresh hash seeds until the graph peels.
    """
    n = int(n)
    if n > cfg.capacity:
        raise ValueError(
            f"stream of {n} fingerprints exceeds frozen capacity "
            f"{cfg.capacity}; grow/resize the level first"
        )
    nq = np.asarray(fq[: cfg.capacity]).astype(np.int32)
    nr = np.asarray(fr[: cfg.capacity]).astype(np.uint32)
    if nq.shape[0] < cfg.capacity:  # short stream: pad the stored run
        pad = cfg.capacity - nq.shape[0]
        nq = np.concatenate([nq, np.full(pad, np.iinfo(np.int32).max, np.int32)])
        nr = np.concatenate([nr, np.full(pad, 0xFFFFFFFF, np.uint32)])
    nq[n:] = np.iinfo(np.int32).max
    nr[n:] = np.uint32(0xFFFFFFFF)

    # dedup: identical p-bit fingerprints are one hyperedge (membership
    # is identical; the run keeps the multiset for merges/stats)
    keep = np.ones(n, bool)
    if n > 1:
        keep[1:] = (nq[1:n] != nq[: n - 1]) | (nr[1:n] != nr[: n - 1])
    uq = jnp.asarray(nq[:n][keep])
    ur = jnp.asarray(nr[:n][keep])
    nu = int(keep.sum())

    table = jnp.zeros((cfg.slots,), jnp.uint32)
    fuse_seed = 0
    if nu:
        for attempt in range(max_attempts):
            fuse_seed = (cfg.seed * 0x9E3779B1 + attempt * 0x85EBCA6B) & 0x7FFFFFFF
            p0, p1, p2, fp = fuse_hash(cfg, uq, ur, fuse_seed)
            h0 = np.asarray(p0)
            h1 = np.asarray(p1)
            h2 = np.asarray(p2)
            rounds = _peel_rounds(h0, h1, h2, cfg.slots)
            if rounds is not None:
                break
        else:
            raise RuntimeError(
                f"binary-fuse peeling failed after {max_attempts} seeds "
                f"(n_unique={nu}, slots={cfg.slots}) — table undersized?"
            )
        # reverse-round assignment: each batch reads final neighbor cells
        for idx, cell in reversed(rounds):
            i = jnp.asarray(idx)
            c = jnp.asarray(cell)
            v = fp[i] ^ table[p0[i]] ^ table[p1[i]] ^ table[p2[i]]
            table = table.at[c].set(v)

    return FuseState(
        table=table,
        run_q=jnp.asarray(nq),
        run_r=jnp.asarray(nr),
        n=jnp.asarray(n, jnp.int32),
        n_unique=jnp.asarray(nu, jnp.int32),
        fuse_seed=jnp.asarray(fuse_seed, jnp.int32),
        overflow=jnp.zeros((), jnp.bool_),
    )


def freeze_keys(cfg: FuseConfig, keys: jnp.ndarray) -> FuseState:
    """Freeze a raw key batch (standalone construction path)."""
    fq, fr = key_fingerprints(cfg, keys)
    order = np.lexsort((np.asarray(fr), np.asarray(fq)))
    return freeze(cfg, np.asarray(fq)[order], np.asarray(fr)[order], keys.shape[0])


# ---------------------------------------------------------------------------
# Probe (reference; the Pallas path lives in repro.kernels)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=0)
def lookup_fp(cfg: FuseConfig, state: FuseState, fq, fr):
    """MAY-CONTAIN for canonical-split fingerprints: 3 gathers + xor.

    Jitted with the config static (the quotient_filter idiom): an eager
    façade ``contains`` compiles once per (cfg, batch shape) instead of
    dispatching the whole hash + 3-gather chain op by op per call.
    """
    p0, p1, p2, fp = fuse_hash(cfg, fq, fr, state.fuse_seed)
    got = state.table[p0] ^ state.table[p1] ^ state.table[p2]
    return (state.n > 0) & (got == fp)


@functools.partial(jax.jit, static_argnums=0)
def contains(cfg: FuseConfig, state: FuseState, keys: jnp.ndarray):
    fq, fr = key_fingerprints(cfg, keys)
    return lookup_fp(cfg, state, fq, fr)


def extract_run(cfg: FuseConfig, state: FuseState):
    """The stored sorted run: ``(fq, fr, n)`` in the canonical split.

    This is the re-expansion path: a merge that consumes a frozen level
    streams these fingerprints back out exactly (the QF ``extract``
    analogue, without a decode — the run is stored directly).
    """
    return state.run_q, state.run_r, state.n
