"""Mesh-sharded quotient filter (the paper's §6 multi-disk future work,
realised as a multi-chip distributed AMQ).

The fingerprint space is partitioned by quotient prefix: shard
``s = f_q >> (q - log2(n_shards))`` owns bucket range
``[s·m/n, (s+1)·m/n)``.  Inserts and lookups route keys to their owner
via a fixed-capacity all_to_all (the MoE-dispatch pattern), then run
the *local* bulk QF ops from quotient_filter.py unchanged — locality is
preserved because a shard's keys form one contiguous quotient range.

Built on shard_map so the collective schedule is explicit (one
all_to_all each way); lowers/compiles on the production mesh in the
dry-run (see tests/test_distributed.py for the 8-device functional run).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import quotient_filter as qf


def _shard_map(f, mesh, in_specs, out_specs):
    """shard_map across jax versions: top-level ``jax.shard_map`` (>=0.5,
    ``check_vma=``) vs ``jax.experimental.shard_map`` (0.4.x, ``check_rep=``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


def _dispatch_capacity(cfg: "ShardedQFConfig", per_shard: int) -> int:
    """Per-(src, dst) bucket capacity for the fixed-size all_to_all.

    A source shard holding ``per_shard`` keys routes ~per_shard/n_shards
    to each owner; sizing is mean + capacity_factor standard deviations
    of the Binomial(per_shard, 1/n) tail (ceil, min 8, multiple of 8) so
    skewed routing does not silently drop keys.
    """
    mean = per_shard / cfg.n_shards
    std = math.sqrt(per_shard * (1 / cfg.n_shards) * (1 - 1 / cfg.n_shards))
    capacity = int(math.ceil(mean + max(6.0, cfg.capacity_factor) * std))
    capacity = max(8, capacity)
    return capacity + (-capacity) % 8


class ShardedQFConfig(NamedTuple):
    q: int  # global log2 buckets
    r: int
    n_shards: int
    axis: str = "data"
    seed: int = 0
    capacity_factor: float = 2.0

    @property
    def shard_bits(self) -> int:
        return int(math.log2(self.n_shards))

    @property
    def local_cfg(self) -> qf.QFConfig:
        return qf.QFConfig(
            q=self.q - self.shard_bits, r=self.r + self.shard_bits, seed=self.seed
        )
        # note: local remainder keeps full fingerprint width so the
        # shard id + local (q, r) reconstruct the global fingerprint


def empty(cfg: ShardedQFConfig) -> qf.QFState:
    """Stacked per-shard states, leading dim = n_shards."""
    local = qf.empty(cfg.local_cfg)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_shards,) + x.shape), local
    )


def _route(cfg: ShardedQFConfig, keys: jnp.ndarray, valid: jnp.ndarray):
    """Owner shard + local fingerprint for each key."""
    fq, fr = qf.fingerprint(keys, cfg.q, cfg.r, cfg.seed)
    owner = (fq >> (cfg.q - cfg.shard_bits)).astype(jnp.int32)
    # local quotient drops the shard prefix; remainder keeps width
    local_q = fq & ((1 << (cfg.q - cfg.shard_bits)) - 1)
    return jnp.where(valid, owner, -1), local_q, fr


def _dispatch(owner, payload, n_shards: int, capacity: int):
    """Bucket payload rows by owner with per-shard capacity (drop excess).

    Returns (buckets (n_shards, capacity, ...), valid (n_shards, capacity)).
    """
    B = owner.shape[0]
    order = jnp.argsort(owner)  # invalid (-1) sort first
    so = owner[order]
    start = jnp.searchsorted(so, jnp.arange(n_shards, dtype=jnp.int32))
    rank = jnp.arange(B, dtype=jnp.int32) - start[jnp.clip(so, 0, n_shards - 1)]
    keep = (so >= 0) & (rank < capacity)
    slot = jnp.where(keep, so * capacity + rank, jnp.int32(2**31 - 1))

    def scat(x):
        return (
            jnp.zeros((n_shards * capacity,) + x.shape[1:], x.dtype)
            .at[slot]
            .set(x[order], mode="drop")
            .reshape(n_shards, capacity, *x.shape[1:])
        )

    bucket_valid = (
        jnp.zeros((n_shards * capacity,), jnp.bool_)
        .at[slot]
        .set(keep, mode="drop")
        .reshape(n_shards, capacity)
    )
    return jax.tree.map(scat, payload), bucket_valid, order, slot


def make_insert(cfg: ShardedQFConfig, mesh: Mesh, batch: int):
    """Builds a jittable sharded bulk-insert: (state, keys) -> state.

    keys arrive sharded over the axis (batch/n_shards per shard); each
    shard buckets ITS OWN keys by owner (local sort), one all_to_all
    delivers every bucket to its owner, and the local bulk QF insert
    runs unchanged.  Exactly the MoE-dispatch collective schedule.
    """
    per_shard = batch // cfg.n_shards
    capacity = _dispatch_capacity(cfg, per_shard)
    local = cfg.local_cfg
    axis = cfg.axis

    def mapped(st, keys_local):
        keys_local = keys_local.reshape(-1)  # (per_shard,)
        valid = jnp.ones(keys_local.shape, jnp.bool_)
        owner, lq, fr = _route(cfg, keys_local, valid)
        (bq, bfr), bvalid, _, _ = _dispatch(
            owner, (lq, fr), cfg.n_shards, capacity
        )
        # (n_dst, cap) -> exchange -> (n_src, cap) rows owned by me
        bq = jax.lax.all_to_all(bq, axis, 0, 0, tiled=True)
        bfr = jax.lax.all_to_all(bfr, axis, 0, 0, tiled=True)
        bvalid = jax.lax.all_to_all(bvalid, axis, 0, 0, tiled=True)
        q_flat, r_flat, v_flat = bq.reshape(-1), bfr.reshape(-1), bvalid.reshape(-1)
        qs, rs = qf._pad_sort(q_flat, r_flat, v_flat)
        st0 = jax.tree.map(lambda x: x[0], st)
        new = qf.insert_sorted(local, st0, qs, rs, jnp.sum(v_flat, dtype=jnp.int32))
        return jax.tree.map(lambda x: x[None], new)

    def insert(state, keys):
        return _shard_map(
            mapped, mesh, in_specs=(P(axis), P(axis)), out_specs=P(axis)
        )(state, keys)

    return insert


def make_lookup(cfg: ShardedQFConfig, mesh: Mesh, batch: int):
    """Builds a jittable sharded lookup: (state, keys) -> present (B,)."""
    per_shard = batch // cfg.n_shards
    capacity = _dispatch_capacity(cfg, per_shard)
    local = cfg.local_cfg
    axis = cfg.axis

    def mapped(st, keys_local):
        keys_local = keys_local.reshape(-1)
        valid = jnp.ones(keys_local.shape, jnp.bool_)
        owner, lq, fr = _route(cfg, keys_local, valid)
        (bq, bfr), bvalid, order, slot = _dispatch(
            owner, (lq, fr), cfg.n_shards, capacity
        )
        bq = jax.lax.all_to_all(bq, axis, 0, 0, tiled=True)
        bfr = jax.lax.all_to_all(bfr, axis, 0, 0, tiled=True)
        st0 = jax.tree.map(lambda x: x[0], st)
        hit = qf.lookup(local, st0, bq.reshape(-1), bfr.reshape(-1))
        # answers travel back to the requesting shard
        hit = jax.lax.all_to_all(
            hit.reshape(cfg.n_shards, capacity), axis, 0, 0, tiled=True
        )
        flat = hit.reshape(-1)
        out_sorted = jnp.where(
            slot < flat.shape[0], flat[jnp.clip(slot, 0, flat.shape[0] - 1)], False
        )
        out = jnp.zeros((per_shard,), jnp.bool_).at[order].set(out_sorted)
        return out

    def lookup(state, keys):
        return _shard_map(
            mapped, mesh, in_specs=(P(axis), P(axis)), out_specs=P(axis)
        )(state, keys)

    return lookup
